"""Fleet CLI — build plans, run fleets (with pluggable launchers and retry
budgets), diagnose and inspect fleet state.

    # declare a whole size/q family as one plan (2 subprocess shards)
    PYTHONPATH=src python -m repro.fleet plan --out plan.json \
        --pallas spmxv --sizes 256,512 --qs 0,1 --modes fp,vmem \
        --shards 2 --reps 2 --backend interpret

    # plan -> spawn -> merge -> classify (resumable; stores are ground truth)
    PYTHONPATH=src python -m repro.fleet run --plan plan.json
    PYTHONPATH=src python -m repro.fleet run --plan plan.json --resume
    PYTHONPATH=src python -m repro.fleet run --plan plan.json --resume \
        --expect-no-measure          # assert a completed fleet replays free

    # real hosts: one worker per host from a declarative hosts.json,
    # flaky shards re-launched automatically up to the retry budget
    PYTHONPATH=src python -m repro.fleet run --plan plan.json \
        --launcher ssh --hosts hosts.json --max-attempts 3 --backoff 2

    # the multi-host path without hosts: deterministic fault injection
    PYTHONPATH=src python -m repro.fleet run --plan plan.json \
        --launcher mock --max-attempts 2

    # statically verify the plan's noise against the compiler (no timing:
    # three small compiles per pair decide whether the payload survives)
    PYTHONPATH=src python -m repro.fleet audit --plan plan.json --expect-clean

    # why is my fleet incomplete?  (per shard: missing ks per pair, torn
    # store to be healed, attempts exhausted; plus any audit failures)
    PYTHONPATH=src python -m repro.fleet doctor --plan plan.json
    PYTHONPATH=src python -m repro.fleet status --plan plan.json

    # live progress while workers run: segmented stores are polled through
    # their manifests alone (no record data is read), so watching never
    # contends with the writers; --once prints one frame and exits
    PYTHONPATH=src python -m repro.fleet watch --plan plan.json --once

docs/orchestration.md documents the hosts.json format, the retry budget,
and the manual fallback recipe for hosts without ssh.
"""
from __future__ import annotations

import argparse
import json
import os
from typing import Optional, Sequence

CAMPAIGN_DIR = "experiments/campaigns/fleet"


def _csv(text: str, cast) -> list:
    return [cast(p.strip()) for p in text.split(",") if p.strip()]


def _parse_mock_script(text: Optional[str]) -> Optional[dict]:
    """``--mock-script`` accepts inline JSON or a path to a JSON file,
    mapping shard index -> per-attempt action list."""
    if text is None:
        return None
    if os.path.exists(text):
        with open(text) as f:
            return json.load(f)
    try:
        return json.loads(text)
    except ValueError:
        raise SystemExit(f"--mock-script: {text!r} is neither a JSON object "
                         "nor a path to one")


def _parse_quality_policy(text: Optional[str]) -> Optional[dict]:
    """``--quality-policy`` accepts inline JSON or a path to a JSON file:
    the plan-embedded measurement-integrity policy (QualityPolicy keys like
    max_spread/sentinel_every/watchdog_floor_s, plus RemeasureBudget keys
    like max_attempts/extra_reps — validated by ``plan.validate()``)."""
    if text is None:
        return None
    if os.path.exists(text):
        with open(text) as f:
            return json.load(f)
    try:
        return json.loads(text)
    except ValueError:
        raise SystemExit(f"--quality-policy: {text!r} is neither a JSON "
                         "object nor a path to one")


def _launcher_spec(args) -> Optional[dict]:
    """The plan-embedded launcher spec the ``plan`` subcommand's flags
    describe (None when no launcher flag was given)."""
    from repro.fleet.launchers import load_hosts

    if not args.launcher:
        if args.hosts or args.mock_script:
            raise SystemExit("plan: --hosts/--mock-script need --launcher")
        return None
    spec: dict = {"kind": args.launcher}
    if args.launcher == "ssh":
        if not args.hosts:
            raise SystemExit("plan: --launcher ssh needs --hosts hosts.json")
        spec["hosts"] = [
            {"addr": h.addr, "python": h.python, "workdir": h.workdir,
             **({"env": dict(h.env)} if h.env else {})}
            for h in load_hosts(args.hosts)]
    elif args.launcher == "mock":
        script = _parse_mock_script(args.mock_script)
        if script is not None:
            spec["script"] = script
    return spec


def _retry_spec(args) -> Optional[dict]:
    """The plan-embedded retry dict described by the retry flags."""
    spec = {}
    if args.max_attempts is not None:
        spec["max_attempts"] = args.max_attempts
    if args.backoff is not None:
        spec["backoff"] = args.backoff
    if args.per_shard_cap is not None:
        spec["per_shard_cap"] = args.per_shard_cap
    return spec or None


def _build_plan(args) -> "object":
    from repro.fleet.plan import PlanError, SweepPlan, TargetSpec

    if bool(args.pallas) == bool(args.arch):
        raise SystemExit("plan: give exactly one of --pallas KERNEL or "
                         "--arch ARCH")
    if args.serve and not args.arch:
        raise SystemExit("plan: --serve needs --arch ARCH")
    if args.pallas:
        from repro.kernels.region import KERNEL_MODES, SIZE_DEFAULT
        if args.pallas not in KERNEL_MODES:
            raise SystemExit(f"unknown pallas kernel {args.pallas!r}; one of "
                             f"{', '.join(sorted(KERNEL_MODES))}")
        modes = (_csv(args.modes, str) if args.modes
                 else list(KERNEL_MODES[args.pallas]))
        params = {"kernel": args.pallas,
                  "sizes": (_csv(args.sizes, int) if args.sizes
                            else [SIZE_DEFAULT[args.pallas]])}
        if args.qs:
            params["qs"] = _csv(args.qs, float)
        if args.nnz_per_row is not None:
            params["nnz_per_row"] = args.nnz_per_row
        spec = TargetSpec("pallas", tuple(modes), params)
        default_name = f"fleet_{args.pallas}"
    elif args.serve:
        from repro.launch.probe import DEFAULT_GRAPH_MODES
        modes = (_csv(args.modes, str) if args.modes
                 else list(DEFAULT_GRAPH_MODES))
        spec = TargetSpec("serve", tuple(modes),
                          {"arch": args.arch, "slots": args.batch,
                           "prompt": args.seq, "max_new": args.max_new})
        default_name = f"fleet_{args.arch}_serve"
    else:
        from repro.launch.probe import DEFAULT_GRAPH_MODES
        modes = (_csv(args.modes, str) if args.modes
                 else list(DEFAULT_GRAPH_MODES))
        spec = TargetSpec("step", tuple(modes),
                          {"arch": args.arch, "kind": args.kind,
                           "seq": args.seq, "batch": args.batch})
        default_name = f"fleet_{args.arch}_{args.kind}"
    name = args.name or default_name
    plan = SweepPlan(name=name,
                     store=args.store or os.path.join(CAMPAIGN_DIR,
                                                      f"{name}.jsonl"),
                     targets=[spec], reps=args.reps, shards=args.shards,
                     workers=args.workers,
                     compile_once=not args.no_compile_once,
                     backend=args.backend,
                     launcher=_launcher_spec(args),
                     retry=_retry_spec(args),
                     store_format=args.store_format,
                     quality=_parse_quality_policy(args.quality_policy))
    try:
        plan.validate()
    except PlanError as e:
        raise SystemExit(f"plan: {e}")
    return plan


def _cmd_plan(args) -> int:
    from repro.fleet.plan import PlanError

    plan = _build_plan(args)
    try:
        grid = plan.grid()       # reject (e.g. duplicate pairs) BEFORE the
    except PlanError as e:       # invalid plan file lands on disk
        raise SystemExit(f"plan: {e}")
    plan.save(args.out)
    print(f"wrote plan {plan.name!r} [{plan.digest()}] -> {args.out}")
    print(f"  {len(grid)} (region, mode) pair(s) over {plan.shards} "
          f"shard(s); store: {plan.store}")
    if plan.launcher:
        print(f"  launcher: {plan.launcher}")
    if plan.retry:
        print(f"  retry: {plan.retry}")
    if plan.quality:
        print(f"  quality: {plan.quality}")
    for r, m in grid:
        print(f"    {r}/{m}")
    print(f"run it:   PYTHONPATH=src python -m repro.fleet run "
          f"--plan {args.out}")
    return 0


def _run_overrides(args, plan):
    """Resolve the run subcommand's launcher/retry overrides against the
    plan's declarative settings (explicit flags win)."""
    from repro.fleet.launchers import (FleetError, RetryBudget,
                                       resolve_launcher)

    if args.in_process and args.launcher and args.launcher != "local":
        raise SystemExit("run: --in-process conflicts with "
                         f"--launcher {args.launcher}")
    try:
        launcher = None
        if args.launcher or args.in_process or args.hosts \
                or args.mock_script:
            launcher = resolve_launcher(
                args.launcher, plan=plan, hosts_path=args.hosts,
                mock_script=_parse_mock_script(args.mock_script),
                in_process=args.in_process)
        retry = None
        rd = dict(plan.retry or {})
        if args.max_attempts is not None:
            rd["max_attempts"] = args.max_attempts
        if args.backoff is not None:
            rd["backoff"] = args.backoff
        if args.per_shard_cap is not None:
            rd["per_shard_cap"] = args.per_shard_cap
        if rd:
            retry = RetryBudget.from_dict(rd)
    except FleetError as e:
        raise SystemExit(f"fleet: {e}")
    return launcher, retry


def _cmd_run(args) -> int:
    from repro.fleet.executor import FleetError, run_fleet
    from repro.fleet.plan import PlanError, SweepPlan

    try:
        plan = SweepPlan.load(args.plan)
    except (OSError, PlanError) as e:
        raise SystemExit(f"fleet: {e}")
    launcher, retry = _run_overrides(args, plan)
    try:
        res = run_fleet(args.plan, resume=args.resume, fresh=args.fresh,
                        expect_no_measure=args.expect_no_measure,
                        launcher=launcher, retry=retry, audit=args.audit,
                        quality=args.quality)
    except FleetError as e:
        raise SystemExit(f"fleet: {e}")
    print(f"fleet {res.plan.name!r} complete: {len(res.reports)} region(s) "
          f"classified, shard(s) launched this run: "
          f"{res.launched or 'none'}")
    return 0


def _cmd_audit(args) -> int:
    """Static noise audit of a plan, standalone: compile every planned pair
    at the audit's two k points, persist the verdicts into the plan's
    canonical store, and exit nonzero when any pair is statically dead
    (``--expect-clean``: when any pair is not fully intact)."""
    from repro.fleet.executor import FleetError, audit_fleet_plan
    from repro.fleet.plan import PlanError, SweepPlan

    try:
        plan = SweepPlan.load(args.plan)
        # gate="warn" so every pair is printed before the exit-code verdict
        records = audit_fleet_plan(plan, gate="warn", force=args.force)
    except (OSError, PlanError, FleetError) as e:
        raise SystemExit(f"audit: {e}")
    grid = plan.grid()
    dead = [k for k in grid
            if records.get(k, {}).get("verdict") == "dead"]
    not_intact = [k for k in grid
                  if records.get(k, {}).get("verdict") != "intact"]
    print(f"== audit verdict: {len(grid) - len(not_intact)}/{len(grid)} "
          f"pair(s) intact, {len(dead)} dead (records -> {plan.store})")
    if args.expect_clean and not_intact:
        print("--expect-clean: not intact: "
              + ", ".join(f"{r}/{m}" for r, m in not_intact))
        return 1
    return 1 if dead else 0


def _cmd_doctor(args) -> int:
    from repro.fleet.executor import FleetError, fleet_doctor
    from repro.fleet.plan import PlanError, SweepPlan

    try:
        plan = SweepPlan.load(args.plan)
        code, report = fleet_doctor(plan, explain=args.explain)
    except (OSError, PlanError, FleetError) as e:
        raise SystemExit(f"doctor: {e}")
    print(report)
    return code


def _cmd_calibrate(args) -> int:
    """Run, inspect or apply a threshold-calibration campaign (the
    known-regime synthetic sweep that fits per-hardware LOW/HIGH —
    ``repro.core.calibration``)."""
    from repro.core import CampaignStore
    from repro.core.absorption import SYNTH_MEASURE_VAR
    from repro.core.calibration import (CALIB_MODES, EXPECTED,
                                        run_calibration)

    store = args.store or os.path.join(CAMPAIGN_DIR, "calibrate.jsonl")
    if args.action == "run":
        from repro.fleet.executor import finish_stats
        from repro.fleet.plan import PlanError, SweepPlan, TargetSpec

        # calibration is definitionally synthetic: the known regimes are
        # forced clock shapes, so make sure the deterministic clock is on
        os.environ.setdefault(SYNTH_MEASURE_VAR, args.base)
        plan = SweepPlan(name="calibrate", store=store, shards=1,
                         reps=args.reps,
                         targets=[TargetSpec("calibrate",
                                             tuple(CALIB_MODES), {})])
        try:
            plan.validate()
        except PlanError as e:
            raise SystemExit(f"calibrate: {e}")
        plan_path = args.out or os.path.splitext(store)[0] + ".plan.json"
        plan.save(plan_path)
        res = run_calibration(store, reps=args.reps)
        tag = ("fitted" if res.fitted
               else "regimes did not separate; FALLBACK to paper defaults")
        print(f"== calibration [{res.hw}]: low={res.low:g} "
              f"high={res.high:g} ({tag})")
        print(f"  plan -> {plan_path}  (doctor --explain shows each "
              "regime's decision path)")
        ok = True
        for name, rep in sorted(res.reports.items()):
            b = rep.bottleneck
            good = b.label == EXPECTED[name]
            ok = ok and good
            verdict = "ok" if good else f"WRONG (expected {EXPECTED[name]})"
            print(f"  {name}: {b.label} "
                  f"(confidence {b.confidence:.3f}) [{verdict}]")
        finish_stats(res.stats, args.expect_no_measure)
        return 0 if ok else 1

    try:   # inspect/apply read an existing store; never create one
        st = CampaignStore(store, readonly=True)
    except FileNotFoundError as e:
        print(e)
        return 2
    if not st.calib:
        print(f"{store}: no calib record — run "
              "`python -m repro.fleet calibrate run` first")
        return 1
    if args.action == "inspect":
        for hw, rec in sorted(st.calib.items()):
            tag = "fitted" if rec.get("fitted") else "FALLBACK"
            print(f"calib hw={hw}: low={rec.get('low'):g} "
                  f"high={rec.get('high'):g} [{tag}] "
                  f"(reps={rec.get('reps')})")
            for s in rec.get("samples", []):
                print(f"  {s['region']}/{s['mode']} [{s['role']}]: "
                      f"Abs^raw={s['k1']:g}")
        return 0
    # apply: copy the calib record(s) into another store, so its future
    # classifications resolve the fitted thresholds
    if not args.to:
        raise SystemExit("calibrate apply needs --to DEST_STORE")
    dest = CampaignStore(args.to)
    for _hw, rec in sorted(st.calib.items()):
        dest.append(rec)
    dest.close()
    print(f"applied {len(st.calib)} calib record(s) -> {args.to}")
    return 0


def _cmd_status(args) -> int:
    from repro.core import CampaignStore, store_exists
    from repro.fleet.executor import FleetState
    from repro.fleet.plan import SweepPlan

    plan = SweepPlan.load(args.plan)
    grid = plan.grid()
    print(f"plan {plan.name!r} [{plan.digest()}]: {len(grid)} pair(s), "
          f"{plan.shards} shard(s), store {plan.store}")
    fleet_path = plan.fleet_path()
    if os.path.exists(fleet_path):
        state = FleetState.load(fleet_path)
        tag = ("" if state.plan_digest == plan.digest()
               else f" (STALE: fleet built by {state.plan_digest})")
        print(f"fleet state {fleet_path}{tag}:")
        for i, ss in sorted(state.shards.items()):
            extra = ""
            if ss.measured is not None:
                extra = f", {ss.measured} measured / {ss.cached} replayed"
            if ss.host:
                extra += f", host {ss.host}"
            print(f"  shard {i}: {ss.status} (attempts={ss.attempts}"
                  f"{extra})")
        if state.classification:
            for name, c in sorted(state.classification.items()):
                print(f"  {name}: {c['label']} ({c['confidence']})")
    else:
        print(f"fleet state {fleet_path}: not created yet")
    incomplete_pairs = 0
    if store_exists(plan.store):
        st = CampaignStore(plan.store, readonly=True)
        status = st.grid_status(grid)
        incomplete_pairs = sum(not ps.complete for ps in status.values())
        print(f"canonical store: {len(grid) - incomplete_pairs}/{len(grid)} "
              "pair(s) complete")
    else:
        incomplete_pairs = len(grid)
        print("canonical store: absent")
    for i in range(plan.shards):
        ws = plan.worker_stores()[i]
        mine = grid[i::plan.shards]
        if not store_exists(ws):
            print(f"  worker store {i}: absent ({len(mine)} pair slice)")
            continue
        st = CampaignStore(ws, readonly=True)
        done = sum(ps.complete for ps in st.grid_status(mine).values())
        print(f"  worker store {i}: {done}/{len(mine)} slice pair(s) "
              "complete")
    return 1 if incomplete_pairs else 0


def _watch_frame(plan, grid) -> tuple[str, bool]:
    """One rendered ``fleet watch`` frame plus grid completeness.

    Segmented stores are summarized from their MANIFESTs alone (sealed
    segment/record/byte totals, live-or-orphan unsealed segments, and the
    aggregated per-pair ``done`` coverage) — no record data is read, so a
    2-second poll never contends with active writers. Legacy single-file
    stores fall back to a full readonly load. ``done`` markers are trusted
    as-is here; ``doctor``/``status`` own the precise per-k check.
    """
    from repro.core import (CampaignStore, is_segmented, manifest_status,
                            store_exists)

    out = [f"== fleet watch: plan {plan.name!r}, {len(grid)} pair(s)"]
    done: set = set()
    stores = [("canonical", plan.store)]
    stores += [(f"worker {i}", ws)
               for i, ws in enumerate(plan.worker_stores())]
    for label, path in stores:
        if not store_exists(path):
            out.append(f"  {label} ({path}): absent")
            continue
        if is_segmented(path):
            st = manifest_status(path)
            seen = sorted((str(r), str(m)) for (r, m), p
                          in st["pairs"].items() if p.get("done"))
            done.update((r, m) for (r, m), p in st["pairs"].items()
                        if p.get("done"))
            extra = (f", {st['orphans']} unsealed segment(s) "
                     f"[{st['orphan_bytes']} B live/orphan]"
                     if st["orphans"] else "")
            out.append(f"  {label} ({path}): {st['segments']} sealed "
                       f"segment(s), {st['records']} record(s), "
                       f"{st['bytes']} B{extra}")
            if seen:
                out.append("    done: " + ", ".join(f"{r}/{m}"
                                                    for r, m in seen))
            quar = sorted((str(r), str(m), p["quarantined"])
                          for (r, m), p in st["pairs"].items()
                          if p.get("quarantined"))
            if quar:
                out.append("    quarantined: " + ", ".join(
                    f"{r}/{m} ({n} point(s))" for r, m, n in quar)
                    + " — doctor names each point and why")
        else:
            st = CampaignStore(path, readonly=True)
            gs = st.grid_status(grid)
            comp = {k for k, ps in gs.items() if ps.complete}
            done.update(comp)
            out.append(f"  {label} ({path}): legacy file, "
                       f"{os.path.getsize(path)} B, {len(comp)}/{len(grid)} "
                       "grid pair(s) complete")
            quar = sorted((r, m, len(ps.quarantined))
                          for (r, m), ps in gs.items() if ps.quarantined)
            if quar:
                out.append("    quarantined: " + ", ".join(
                    f"{r}/{m} ({n} point(s))" for r, m, n in quar)
                    + " — doctor names each point and why")
    missing = [k for k in grid if k not in done]
    line = (f"  grid: {len(grid) - len(missing)}/{len(grid)} "
            "pair(s) done")
    if missing:
        head = ", ".join(f"{r}/{m}" for r, m in missing[:6])
        line += (f" — waiting on {head}"
                 + (f" (+{len(missing) - 6} more)" if len(missing) > 6
                    else ""))
    out.append(line)
    return "\n".join(out), not missing


def _cmd_watch(args) -> int:
    import time

    from repro.fleet.plan import PlanError, SweepPlan

    try:
        plan = SweepPlan.load(args.plan)
        grid = plan.grid()
    except (OSError, PlanError) as e:
        raise SystemExit(f"watch: {e}")
    while True:
        frame, complete = _watch_frame(plan, grid)
        print(frame, flush=True)
        if complete:
            return 0
        if args.once:
            return 1
        time.sleep(max(0.2, args.interval))


def _add_launcher_flags(p, *, for_plan: bool) -> None:
    """The launcher/retry flag set shared by ``plan`` (serialize into the
    plan) and ``run`` (override the plan for this invocation)."""
    where = "serialize into the plan" if for_plan else "override the plan"
    p.add_argument("--launcher", default=None,
                   choices=("local", "ssh", "mock"),
                   help=f"shard launcher kind ({where}); default: local "
                        "subprocesses")
    p.add_argument("--hosts", default=None, metavar="HOSTS.json",
                   help="ssh host specs: a JSON list (or {\"hosts\": [...]})"
                        " of {addr, python, workdir, env} objects")
    p.add_argument("--mock-script", default=None, metavar="JSON",
                   help="mock launcher fault script (inline JSON or a file):"
                        " {shard: [action per attempt]}, actions ok|crash|"
                        "drop-point|timeout|dead")
    p.add_argument("--max-attempts", type=int, default=None,
                   help="launch rounds per run before giving up (retry "
                        "budget; default 1)")
    p.add_argument("--backoff", type=float, default=None,
                   help="seconds to sleep before retry round r, doubling "
                        "each round (default 0)")
    p.add_argument("--per-shard-cap", type=int, default=None,
                   help="LIFETIME attempts one shard may consume across "
                        "resumes (0 = unlimited)")


def build_parser() -> argparse.ArgumentParser:
    """The fleet CLI's argparse tree (exposed for help/doc tests)."""
    ap = argparse.ArgumentParser(
        prog="python -m repro.fleet",
        description="fleet orchestrator: plan, spawn (local/ssh/mock "
                    "launchers with retry budgets), merge, classify")
    sub = ap.add_subparsers(dest="cmd", required=True)

    pp = sub.add_parser("plan", help="build a SweepPlan JSON")
    pp.add_argument("--out", required=True, help="plan JSON path to write")
    pp.add_argument("--name", default=None,
                    help="plan name (default: derived from the target)")
    pp.add_argument("--store", default=None,
                    help=f"campaign store (default: under {CAMPAIGN_DIR}/)")
    pp.add_argument("--store-format", default=None,
                    choices=("jsonl", "segments"),
                    help="store layout: one legacy JSONL file (default) or "
                         "an append-only segment directory with a "
                         "checksummed manifest (incremental merges, "
                         "manifest-driven fleet watch)")
    pp.add_argument("--pallas", default=None, metavar="KERNEL",
                    help="pallas kernel family target "
                         "(matmul|spmxv|attention|probe)")
    pp.add_argument("--sizes", default=None,
                    help="comma list for the kernel's size knob "
                         "(rows / seq / grid steps)")
    pp.add_argument("--qs", default=None,
                    help="comma list of swap probabilities (spmxv only)")
    pp.add_argument("--nnz-per-row", type=int, default=None,
                    help="spmxv nonzeros per row")
    pp.add_argument("--arch", default=None,
                    help="model-step target architecture")
    pp.add_argument("--serve", action="store_true",
                    help="with --arch: plan a 'serve' target (the paged "
                         "serving engine's prefill + decode regions; --seq "
                         "is the prompt length, --batch the slot count)")
    pp.add_argument("--max-new", type=int, default=8,
                    help="decode budget per request of a --serve target")
    pp.add_argument("--kind", default="train", choices=("train", "decode"),
                    help="model-step flavour to probe")
    pp.add_argument("--seq", type=int, default=128,
                    help="model-step sequence length")
    pp.add_argument("--batch", type=int, default=4,
                    help="model-step batch size")
    pp.add_argument("--modes", default=None,
                    help="comma list (default: the target's full mode set)")
    pp.add_argument("--reps", type=int, default=2,
                    help="timing repetitions per measured point")
    pp.add_argument("--shards", type=int, default=2,
                    help="how many workers the grid splits across")
    pp.add_argument("--workers", type=int, default=1,
                    help="threads per shard")
    pp.add_argument("--backend", default="auto",
                    choices=("auto", "interpret", "pallas"),
                    help="pallas execution backend")
    pp.add_argument("--no-compile-once", action="store_true",
                    help="force the trace-per-k fallback sweep path")
    pp.add_argument("--quality-policy", default=None, metavar="JSON",
                    help="serialize a runtime measurement-integrity policy "
                         "into the plan (inline JSON or a file): "
                         "QualityPolicy keys (max_spread, timer_floor_s, "
                         "sentinel_every, sentinel_tol, watchdog_margin, "
                         "watchdog_floor_s) plus RemeasureBudget keys "
                         "(max_attempts, extra_reps, max_total_reps); "
                         "workers then variance-gate, sentinel-check and "
                         "watchdog every measured point")
    _add_launcher_flags(pp, for_plan=True)
    pp.set_defaults(fn=_cmd_plan)

    rp = sub.add_parser("run", help="plan -> spawn shards (retrying up to "
                                    "the budget) -> merge -> classify")
    rp.add_argument("--plan", required=True,
                    help="the SweepPlan JSON to execute")
    rp.add_argument("--resume", action="store_true",
                    help="continue an existing fleet: re-launch only "
                         "incomplete shards (quarantined points count as "
                         "incomplete and are re-measured); a clean complete "
                         "fleet replays with zero new measurements")
    rp.add_argument("--fresh", action="store_true",
                    help="delete this plan's stores and fleet state first")
    rp.add_argument("--expect-no-measure", action="store_true",
                    help="exit non-zero if the finalize replay had to "
                         "measure anything")
    rp.add_argument("--in-process", action="store_true",
                    help="run shards sequentially in this process instead "
                         "of spawning subprocesses")
    rp.add_argument("--audit", default="gate",
                    choices=("gate", "warn", "off"),
                    help="static noise-audit policy before launch: gate "
                         "(default) refuses statically-dead pairs, warn "
                         "measures anyway, off skips the audit")
    rp.add_argument("--quality", default="gate",
                    choices=("gate", "warn", "off"),
                    help="runtime measurement-quality policy after the "
                         "merge: gate (default) refuses a majority-"
                         "quarantined classification, warn reports it, off "
                         "attaches no quality evidence (the plan's quality "
                         "policy still guards the measurements themselves)")
    _add_launcher_flags(rp, for_plan=False)
    rp.set_defaults(fn=_cmd_run)

    audp = sub.add_parser("audit", help="statically verify every planned "
                                        "(region, mode) pair against the "
                                        "compiler — no measurements; exit 1 "
                                        "on any dead pair")
    audp.add_argument("--plan", required=True,
                      help="the SweepPlan JSON to audit")
    audp.add_argument("--expect-clean", action="store_true",
                      help="exit 1 unless EVERY pair is fully intact "
                           "(degraded pairs also fail)")
    audp.add_argument("--force", action="store_true",
                      help="re-audit pairs that already carry audit records "
                           "(fresh records supersede)")
    audp.set_defaults(fn=_cmd_audit)

    dp = sub.add_parser("doctor", help="explain per shard why the fleet is "
                                       "incomplete: missing ks per pair, "
                                       "torn store to be healed, attempts "
                                       "exhausted (exit 1 while incomplete)")
    dp.add_argument("--plan", required=True,
                    help="the SweepPlan JSON to diagnose")
    dp.add_argument("--explain", action="store_true",
                    help="for a covered grid, also replay each region's "
                         "classification (measurement-free) and print the "
                         "strategy tree's decision path: which node fired, "
                         "under which thresholds (calibrated or default), "
                         "plus any audit/quality downgrades")
    dp.set_defaults(fn=_cmd_doctor)

    cal = sub.add_parser("calibrate",
                         help="threshold calibration: run the known-regime "
                              "synthetic sweep and fit per-hardware "
                              "LOW/HIGH, inspect the fitted record, or "
                              "apply it to another store")
    cal.add_argument("action", choices=("run", "inspect", "apply"),
                     help="run: sweep the four known-regime kernels under "
                          "the deterministic synthetic clock and persist a "
                          "calib record; inspect: print the store's calib "
                          "record(s); apply: copy them into --to DEST")
    cal.add_argument("--store", default=None,
                     help="calibration campaign store (default: "
                          f"{CAMPAIGN_DIR}/calibrate.jsonl)")
    cal.add_argument("--out", default=None, metavar="PLAN.json",
                     help="where `run` writes the calibrate SweepPlan "
                          "(default: next to the store), for doctor/status/"
                          "inspect --plan")
    cal.add_argument("--reps", type=int, default=2,
                     help="timing repetitions per measured point")
    cal.add_argument("--base", default="1e-3",
                     help="synthetic-clock base seconds exported as "
                          "REPRO_SYNTH_MEASURE when it is not already set")
    cal.add_argument("--to", default=None, metavar="DEST_STORE",
                     help="apply: the store that receives the calib "
                          "record(s)")
    cal.add_argument("--expect-no-measure", action="store_true",
                     help="run: exit non-zero if the calibration had to "
                          "measure anything (replay contract)")
    cal.set_defaults(fn=_cmd_calibrate)

    sp = sub.add_parser("status", help="show fleet/shard/store completeness "
                                       "(exit 1 while incomplete)")
    sp.add_argument("--plan", required=True,
                    help="the SweepPlan JSON to summarize")
    sp.set_defaults(fn=_cmd_status)

    wp = sub.add_parser("watch", help="live store progress: manifest-driven "
                                      "for segmented stores (no record "
                                      "reads), polled until the grid is "
                                      "done")
    wp.add_argument("--plan", required=True,
                    help="the SweepPlan JSON to watch")
    wp.add_argument("--interval", type=float, default=2.0,
                    help="seconds between frames (default 2)")
    wp.add_argument("--once", action="store_true",
                    help="print one frame and exit (1 while incomplete)")
    wp.set_defaults(fn=_cmd_watch)
    return ap


def main(argv: Optional[Sequence[str]] = None) -> int:
    """CLI entry: dispatch to the plan/run/audit/doctor/calibrate/status/
    watch subcommand."""
    args = build_parser().parse_args(argv)
    return args.fn(args)


if __name__ == "__main__":
    raise SystemExit(main())

"""SweepPlan — the declarative unit of fleet work.

A plan enumerates the FULL measurement grid up front — regions × modes × ks
× reps × kernel size/q families — and serializes to JSON next to the store,
so every participant (the launcher, each worker subprocess, a human at the
``inspect`` CLI) agrees on exactly the same grid in exactly the same order:

  * ``targets`` is a list of declarative ``TargetSpec``s, not live objects —
    a spec resolves to one or more RegionTargets in whatever process needs
    them (the whole point: a subprocess shard rebuilds its regions from the
    plan file alone);
  * a "pallas" spec spans a whole size/q FAMILY (``kernels.region.
    pallas_family``): one plan — and one campaign store — holds a kernel's
    entire grid;
  * ``pairs()``/``grid()`` fix the canonical (region, mode) enumeration
    (region-major, mode-minor, targets in declaration order). Worker ``i`` of
    ``N`` measures every N-th pair — the same slicing as
    ``Campaign.measure_pairs`` — so the plan file IS the shard assignment;
  * ``digest()`` hashes the canonical JSON; fleet state pins it so a resumed
    fleet can refuse to splice shards measured under a different plan.

Plan JSON (one object, schema-versioned):

  {"sweep_plan": 1, "name": ..., "store": ..., "reps": 2, "shards": 2,
   "workers": 1, "compile_once": true, "backend": "interpret",
   "targets": [{"kind": "pallas", "modes": ["fp", "vmem"],
                "params": {"kernel": "spmxv", "sizes": [256, 512],
                           "qs": [0.0, 1.0], "nnz_per_row": 16}},
               {"kind": "step", "modes": ["fp_add32", "vmem_ld"],
                "params": {"arch": "gemma_2b", "kind": "train",
                           "seq": 64, "batch": 2}}]}
"""
from __future__ import annotations

import dataclasses
import hashlib
import json
import os
from typing import Optional

PLAN_SCHEMA = 1


class PlanError(ValueError):
    """A plan file (or plan construction) is invalid."""


@dataclasses.dataclass(frozen=True)
class TargetSpec:
    """One declarative target family: what to measure and under which modes.

    kinds:
      * "pallas" — params {kernel, sizes[, qs, ...spec kwargs]}; resolves via
        ``pallas_family`` to one RegionTarget per size/q;
      * "step"   — params {arch[, kind, seq, batch]}; resolves via
        ``repro.launch.probe.build_step_region`` to one model-step region;
      * "serve"  — params {arch[, slots, prompt, max_new, page_size]};
        resolves via ``repro.serve.load.build_serve_regions`` to TWO regions
        of one paged serving workload: the engine's batched prefill and its
        decode tick, probed (and classified) separately;
      * "calibrate" — params {[n, chunk]}; resolves via
        ``repro.core.calibration.calibrate_targets`` to the four
        known-regime threshold-calibration regions (synthetic-clock only).
    """
    kind: str
    modes: tuple[str, ...]
    params: dict

    def validate(self) -> None:
        """Reject unknown kinds/modes/params at plan-build time (a bad
        family must not fail later in every worker subprocess)."""
        if not self.modes:
            raise PlanError(f"target {self.kind!r} has no modes")
        if self.kind == "pallas":
            from repro.kernels.region import KERNEL_MODES, check_family_args
            kernel = self.params.get("kernel")
            if kernel not in KERNEL_MODES:
                raise PlanError(f"unknown pallas kernel {kernel!r}; one of "
                                f"{sorted(KERNEL_MODES)}")
            sizes = self.params.get("sizes")
            if not sizes:
                raise PlanError(f"pallas target {kernel!r} needs a non-empty "
                                "sizes list")
            try:
                # full family-argument rules (qs scope, unknown spec params,
                # size alignment) — a bad family must fail at plan BUILD
                # time, not in every worker subprocess at resolve time
                check_family_args(kernel, sizes, self.params.get("qs"),
                                  self._extra_params())
            except ValueError as e:
                raise PlanError(str(e)) from e
            bad = [m for m in self.modes if m not in KERNEL_MODES[kernel]]
            if bad:
                raise PlanError(f"kernel {kernel!r} supports modes "
                                f"{KERNEL_MODES[kernel]}, not {bad}")
        elif self.kind in ("step", "serve"):
            if not self.params.get("arch"):
                raise PlanError(f"{self.kind} target needs an 'arch'")
            from repro.core.noise import make_modes
            bad = [m for m in self.modes if m not in make_modes()]
            if bad:
                raise PlanError(f"unknown graph-level mode(s) {bad}")
            if self.kind == "serve":
                for key in ("slots", "prompt", "max_new", "page_size"):
                    v = self.params.get(key)
                    if v is not None and (not isinstance(v, int) or v < 1):
                        raise PlanError(f"serve target {key}={v!r}: want a "
                                        "positive int")
        elif self.kind == "calibrate":
            from repro.core.calibration import CALIB_MODES
            bad = [m for m in self.modes if m not in CALIB_MODES]
            if bad:
                raise PlanError(f"calibrate targets sweep the loop modes "
                                f"{list(CALIB_MODES)}, not {bad}")
            unknown = sorted(set(self.params) - {"n", "chunk"})
            if unknown:
                raise PlanError(f"unknown calibrate param(s) {unknown}")
            for key in ("n", "chunk"):
                v = self.params.get(key)
                if v is not None and (not isinstance(v, int) or v < 1):
                    raise PlanError(f"calibrate target {key}={v!r}: want a "
                                    "positive int")
        else:
            raise PlanError(f"unknown target kind {self.kind!r}; "
                            "one of ['calibrate', 'pallas', 'step', "
                            "'serve']")

    def _extra_params(self) -> dict:
        return {k: v for k, v in self.params.items()
                if k not in ("kernel", "sizes", "qs")}

    def resolve(self, backend: str = "auto") -> list:
        """Build this spec's RegionTargets (in the calling process)."""
        if self.kind == "pallas":
            from repro.kernels.region import pallas_family
            return pallas_family(self.params["kernel"], self.params["sizes"],
                                 qs=self.params.get("qs"), backend=backend,
                                 **self._extra_params())
        p = self.params
        if self.kind == "calibrate":
            from repro.core.calibration import calibrate_targets
            return calibrate_targets(n=int(p.get("n", 4096)),
                                     chunk=int(p.get("chunk", 512)))
        if self.kind == "serve":
            from repro.serve.load import build_serve_regions
            return build_serve_regions(
                p["arch"], list(self.modes), slots=int(p.get("slots", 4)),
                prompt=int(p.get("prompt", 32)),
                max_new=int(p.get("max_new", 8)),
                page_size=int(p.get("page_size", 16)))
        from repro.launch.probe import build_step_region
        return [build_step_region(p["arch"], p.get("kind", "train"),
                                  list(self.modes), seq=int(p.get("seq", 128)),
                                  batch=int(p.get("batch", 4)))]

    def region_names(self) -> list[str]:
        """The names ``resolve()``'s regions will carry, derived WITHOUT
        building anything — grid queries (status, inspect, the launcher's
        completeness checks) must stay cheap even for model-step targets."""
        if self.kind == "pallas":
            from repro.kernels.region import family_names
            return family_names(self.params["kernel"], self.params["sizes"],
                                qs=self.params.get("qs"),
                                **self._extra_params())
        p = self.params
        if self.kind == "calibrate":
            from repro.core.calibration import REGIME_NAMES
            return list(REGIME_NAMES)
        if self.kind == "serve":
            from repro.serve.load import serve_region_names
            return serve_region_names(p["arch"],
                                      slots=int(p.get("slots", 4)),
                                      prompt=int(p.get("prompt", 32)),
                                      max_new=int(p.get("max_new", 8)),
                                      page_size=int(p.get("page_size", 16)))
        from repro.configs import get_smoke_config   # a dataclass, no jax
        return [f"{get_smoke_config(p['arch']).name}_{p.get('kind', 'train')}"
                f"_s{int(p.get('seq', 128))}_b{int(p.get('batch', 4))}"]

    def to_dict(self) -> dict:
        """The JSON-able form embedded in a plan's ``targets`` list."""
        return {"kind": self.kind, "modes": list(self.modes),
                "params": self.params}

    @classmethod
    def from_dict(cls, d: dict) -> "TargetSpec":
        """Rebuild a spec from its plan-JSON entry."""
        return cls(kind=d.get("kind", ""), modes=tuple(d.get("modes", ())),
                   params=dict(d.get("params", {})))


@dataclasses.dataclass
class SweepPlan:
    """The full declarative grid plus every setting that shapes measurement
    (reps, compile path, backend) and distribution (shards, threads, and —
    when declared — the launcher and retry policy).

    ``launcher`` (optional) declares HOW shards are spawned:
    ``{"kind": "local"}`` (subprocesses, the default),
    ``{"kind": "ssh", "hosts": [{addr, python, workdir, env}, ...]}``, or
    ``{"kind": "mock", "script": {"0": ["crash"], ...}}`` for deterministic
    fault injection. ``retry`` (optional) declares the ``RetryBudget``:
    ``{"max_attempts": N, "backoff": s, "per_shard_cap": M}``. Both are
    serialized into the digest when set (a different cluster layout or
    retry policy is a different plan identity); when absent, the digest is
    byte-identical to a pre-launcher plan.

    ``store_format`` (optional) selects the campaign-store layout:
    ``"jsonl"`` (one legacy file, the default) or ``"segments"``
    (``repro.core.segments`` — append-only segments + manifest, giving
    incremental merges and ``fleet watch`` live status). Serialized — and
    hashed into the digest — only when set, like launcher/retry.

    ``quality`` (optional) declares the runtime measurement-integrity
    guard: one flat dict of ``repro.core.quality`` QualityPolicy and
    RemeasureBudget fields (e.g. ``{"max_spread": 0.15, "sentinel_every":
    4, "watchdog_floor_s": 0.5, "max_attempts": 2}``). Workers then
    dispersion-gate every fresh point, interleave baseline sentinels,
    quarantine what can't be trusted, and re-measure quarantined points on
    resume. Serialized — and hashed — only when set: measurement validity
    thresholds are part of the plan's identity.
    """
    name: str
    store: str
    targets: list[TargetSpec]
    reps: int = 2
    shards: int = 1
    workers: int = 1
    compile_once: bool = True
    backend: str = "auto"
    launcher: Optional[dict] = None
    retry: Optional[dict] = None
    store_format: Optional[str] = None
    quality: Optional[dict] = None

    # -- validation / identity ----------------------------------------------
    def validate(self) -> None:
        """Reject malformed plans (empty grids, bad sizes, unknown modes,
        invalid launcher/retry specs) before they land on disk."""
        if not self.name:
            raise PlanError("plan needs a name")
        if not self.store:
            raise PlanError("plan needs a store path")
        if not self.targets:
            raise PlanError("plan has no targets")
        if self.shards < 1 or self.workers < 1 or self.reps < 1:
            raise PlanError("shards, workers and reps must be >= 1")
        for spec in self.targets:
            spec.validate()
        self._validate_distribution()

    def _validate_distribution(self) -> None:
        """Validate the optional launcher/retry specs (lazy import: the
        launchers module sits above plan in the layer order)."""
        from repro.fleet import launchers as ln

        if self.store_format not in (None, "jsonl", "segments"):
            raise PlanError(f"store_format {self.store_format!r} unknown; "
                            "one of ['jsonl', 'segments']")
        if (self.store_format == "segments" and self.launcher is not None
                and self.launcher.get("kind") == "ssh"):
            # the ssh launcher pushes/pulls ONE file per worker store; a
            # segment directory doesn't fit that staging protocol yet
            raise PlanError("store_format 'segments' is not supported with "
                            "the ssh launcher (single-file staging); use "
                            "local/mock, or the default jsonl layout")
        if self.launcher is not None:
            kind = self.launcher.get("kind")
            if kind not in ln.LAUNCHER_KINDS:
                raise PlanError(f"launcher kind {kind!r} unknown; one of "
                                f"{list(ln.LAUNCHER_KINDS)}")
            unknown = sorted(set(self.launcher)
                             - {"kind", "hosts", "script", "in_process"})
            if unknown:
                raise PlanError(f"unknown launcher key(s) {unknown}")
            try:
                if kind == "ssh":
                    hosts = [ln.HostSpec.from_dict(h)
                             for h in self.launcher.get("hosts", [])]
                    if not hosts:
                        raise PlanError("ssh launcher spec needs a "
                                        "non-empty hosts list")
                elif kind == "mock":
                    ln.MockClusterLauncher(self.launcher.get("script"))
            except ln.FleetError as e:
                raise PlanError(str(e)) from e
        if self.retry is not None:
            try:
                ln.RetryBudget.from_dict(self.retry)
            except ln.FleetError as e:
                raise PlanError(str(e)) from e
        if self.quality is not None:
            from repro.core.quality import quality_from_dict
            try:
                quality_from_dict(self.quality)
            except ValueError as e:
                raise PlanError(str(e)) from e

    def to_dict(self) -> dict:
        """The canonical JSON-able form; ``launcher``/``retry`` appear only
        when declared, so plans without them keep their pre-launcher
        digest."""
        d = {"sweep_plan": PLAN_SCHEMA, "name": self.name,
             "store": self.store, "reps": self.reps,
             "shards": self.shards, "workers": self.workers,
             "compile_once": self.compile_once, "backend": self.backend,
             "targets": [t.to_dict() for t in self.targets]}
        if self.launcher is not None:
            d["launcher"] = self.launcher
        if self.retry is not None:
            d["retry"] = self.retry
        if self.store_format is not None:
            d["store_format"] = self.store_format
        if self.quality is not None:
            d["quality"] = self.quality
        return d

    def canonical_json(self) -> str:
        """``to_dict`` with sorted keys — the digest's input bytes."""
        return json.dumps(self.to_dict(), sort_keys=True)

    def digest(self) -> str:
        """Content hash pinning the grid AND the measurement settings —
        fleet state refuses to splice shards from a different digest."""
        return hashlib.sha256(self.canonical_json().encode()).hexdigest()[:12]

    # -- persistence --------------------------------------------------------
    def save(self, path: str) -> str:
        """Validate, then atomically write the plan JSON (with its digest
        echoed for humans) to ``path``; returns ``path``."""
        self.validate()
        d = os.path.dirname(path)
        if d:
            os.makedirs(d, exist_ok=True)
        tmp = path + ".tmp"
        with open(tmp, "w") as f:
            json.dump({**self.to_dict(), "digest": self.digest()}, f,
                      indent=1, sort_keys=True)
            f.write("\n")
        os.replace(tmp, path)
        return path

    @classmethod
    def from_dict(cls, d: dict) -> "SweepPlan":
        """Rebuild (and validate) a plan from its JSON object; plans saved
        before the launcher/retry fields existed load unchanged."""
        if d.get("sweep_plan") != PLAN_SCHEMA:
            raise PlanError(f"not a sweep plan (sweep_plan="
                            f"{d.get('sweep_plan')!r}, want {PLAN_SCHEMA})")
        plan = cls(name=d.get("name", ""), store=d.get("store", ""),
                   targets=[TargetSpec.from_dict(t)
                            for t in d.get("targets", [])],
                   reps=int(d.get("reps", 2)), shards=int(d.get("shards", 1)),
                   workers=int(d.get("workers", 1)),
                   compile_once=bool(d.get("compile_once", True)),
                   backend=d.get("backend", "auto"),
                   launcher=d.get("launcher"), retry=d.get("retry"),
                   store_format=d.get("store_format"),
                   quality=d.get("quality"))
        plan.validate()
        return plan

    @classmethod
    def load(cls, path: str) -> "SweepPlan":
        """Load and validate a plan JSON file."""
        with open(path) as f:
            return cls.from_dict(json.load(f))

    # -- the canonical grid --------------------------------------------------
    def resolve(self) -> list[tuple[TargetSpec, list]]:
        """Resolve every spec (cached: a plan resolves once per process, so
        all grid queries see the SAME RegionTarget objects)."""
        if getattr(self, "_resolved", None) is None:
            self._resolved = [(spec, spec.resolve(self.backend))
                              for spec in self.targets]
        return self._resolved

    def pairs(self) -> list[tuple[object, str]]:
        """The full (RegionTarget, mode) grid in canonical order — the exact
        sequence ``Campaign.measure_pairs`` slices across workers."""
        return [(region, mode) for spec, regions in self.resolve()
                for region in regions for mode in spec.modes]

    def grid(self) -> list[tuple[str, str]]:
        """The grid by (region name, mode), WITHOUT resolving targets —
        completeness queries, status and the launcher stay cheap (a step
        target otherwise builds a whole model just to learn its name).
        Same enumeration order as ``pairs()``; pinned by tests."""
        out = [(name, mode) for spec in self.targets
               for name in spec.region_names() for mode in spec.modes]
        if len(set(out)) != len(out):
            raise PlanError(f"plan {self.name!r} enumerates duplicate "
                            "(region, mode) pairs; targets must not overlap")
        return out

    # -- derived paths -------------------------------------------------------
    def worker_stores(self) -> list[str]:
        """Every shard's worker-store path (``store.wIofN.jsonl``)."""
        from repro.core.campaign import worker_store
        return [worker_store(self.store, i, self.shards)
                for i in range(self.shards)]

    def fleet_path(self) -> str:
        """Where this plan's ``fleet.json`` ledger lives."""
        return os.path.splitext(self.store)[0] + ".fleet.json"

    def report_path(self) -> str:
        """Where this plan's canonical ``report.json`` lands."""
        return os.path.splitext(self.store)[0] + ".report.json"

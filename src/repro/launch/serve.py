"""Serving launcher: batched generation with continuous batching over the
paged KV-cache pool (``--dense`` forces the per-slot dense layout).

    PYTHONPATH=src python -m repro.launch.serve --arch gemma-2b --smoke \
        --requests 8 --slots 4 --max-new 16
"""
from __future__ import annotations

import argparse
import time

import jax
import numpy as np


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--max-seq", type=int, default=256)
    ap.add_argument("--page-size", type=int, default=16)
    ap.add_argument("--dense", action="store_true",
                    help="force the dense (non-paged) cache layout")
    ap.add_argument("--temperature", type=float, default=0.0)
    args = ap.parse_args()

    from repro.configs import get_config, get_smoke_config
    from repro.models.model import build
    from repro.serve import ServeEngine

    cfg = get_smoke_config(args.arch) if args.smoke else get_config(args.arch)
    api = build(cfg)
    params = api.init(jax.random.PRNGKey(0))
    eng = ServeEngine(api, params, n_slots=args.slots, max_seq=args.max_seq,
                      temperature=args.temperature,
                      page_size=args.page_size,
                      paged=False if args.dense else None)

    rng = np.random.RandomState(0)
    reqs = []
    for i in range(args.requests):
        plen = int(rng.randint(2, 12))
        prompt = rng.randint(0, cfg.vocab_size, size=plen).tolist()
        reqs.append(eng.submit(prompt, max_new=args.max_new))

    t0 = time.perf_counter()
    eng.run(max_ticks=args.requests * (args.max_new + 4))
    dt = time.perf_counter() - t0
    n_tok = sum(len(r.out) for r in reqs)
    layout = "paged" if eng.paged else "dense"
    print(f"{len(reqs)} requests on {args.slots} slots ({layout}) -> "
          f"{n_tok} tokens in {dt:.2f}s ({n_tok/dt:.1f} tok/s)")
    rep = eng.report()
    print(f"  prefill calls: {rep['prefill_calls']}, mean pool occupancy: "
          f"{rep['mean_pool_occupancy']:.2f}")
    for r in reqs[:4]:
        print(f"  req {r.uid}: prompt={r.prompt[:6]}... out={r.out[:8]}... "
              f"done={r.done}")


if __name__ == "__main__":
    main()

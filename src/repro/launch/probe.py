"""Noise-injection bottleneck probe — the paper's tool applied to this
framework's own train/serve steps and to the Pallas kernel layer, and the
FLEET's single-process worker entry.

Every measured path runs through the fleet plan/executor spine
(``repro.fleet``): the CLI flags build a one-target ``SweepPlan`` and hand it
to ``run_worker`` — the same code path a fleet shard executes — so ad-hoc
probes, subprocess shards, and declarative plan files all measure through
one campaign tail (store naming, shard dispatch, reporting).

Measured mode (default; reduced config, host backend) runs as a resumable
CAMPAIGN: every (mode, k, t) point persists to a JSONL store under
``experiments/campaigns/`` and re-running skips everything already measured.
The sweep itself uses the controller's compile-once path (one runtime-k
executable per mode instead of one per sweep point):

    PYTHONPATH=src python -m repro.launch.probe --arch gemma-2b --smoke \
        --kind train --modes fp_add32,vmem_ld,hbm_stream \
        [--store PATH] [--fresh] [--workers N] [--no-compile-once]

Serve mode probes the paged serving engine as TWO regions — the batched
prefill and the decode tick — under one campaign, so the two phases of one
workload classify separately (docs/methodology.md §Serving):

    PYTHONPATH=src python -m repro.launch.probe --serve --arch gemma-2b \
        --seq 16 --batch 4 [--modes fp_add32,hbm_stream] [--store PATH]

Pallas mode probes one of the real kernels (matmul / spmxv / attention /
probe; interpret mode off-TPU) through the SAME campaign machinery — the
noise quantity is a runtime operand of the kernel itself, so the whole
sweep compiles ≤2 Pallas executables per mode:

    PYTHONPATH=src python -m repro.launch.probe --pallas spmxv \
        [--modes fp,vmem] [--store PATH] [--expect-no-measure]

Fleet worker mode executes a slice of a saved ``SweepPlan`` — this is what
``python -m repro.fleet run`` spawns (through any of its launchers: local
subprocesses, ssh hosts, the mock cluster) and the per-host command of the
manual multi-host recipe (docs/orchestration.md). Launchers hand the worker
a handshake env: ``REPRO_FLEET_EXPECT_DIGEST`` (the worker refuses to run
if its plan file's digest disagrees — an out-of-sync plan copy on one host
must not splice a different grid into the fleet) and ``REPRO_FLEET_HOST``
(echoed in the worker banner and the fleet ledger's attempt log):

    PYTHONPATH=src python -m repro.launch.probe --plan plan.json --shard 0/2
    PYTHONPATH=src python -m repro.launch.probe --plan plan.json \
        --expect-no-measure        # whole plan in-process; replay check

Legacy ad-hoc fan-out still works: ``--shard I/N`` without ``--plan``
measures a disjoint slice of the flag-built grid into a per-worker store;
merge afterwards with ``python -m repro.core.campaign merge`` (or just run
the same grid as a plan through ``repro.fleet``, which merges for you).

``--expect-no-measure`` turns "the store fully covers this probe" into an
exit code, so scripts and CI can assert the round-trip measured nothing.

Every measured path classifies under the store's calibrated thresholds when
a ``calib`` record is present (``python -m repro.fleet calibrate run`` fits
one; ``... calibrate apply --to STORE`` copies it into a probe's store) and
falls back to the paper defaults otherwise — the worker banner prints the
threshold provenance whenever it is not the default.

Analytic mode (full config, TPU v5e target, reads the dry-run artifact) runs
through the SAME campaign machinery — predictions persist as ``pred``
records (curve + fit + HardwareConfig/terms/settings) and replay on re-run:

    PYTHONPATH=src python -m repro.launch.probe --arch gemma-2b \
        --shape train_4k --analytic [--dryrun-dir experiments/dryrun/16x16] \
        [--store PATH] [--fresh]

All paths report Abs^raw per mode + the bottleneck classification; measured
modes also verify the payload statically (surviving noise ops in optimized
HLO, or the exact nacc oracle for Pallas kernels)."""
from __future__ import annotations

import argparse
import json
import os
from typing import Optional, Sequence

import jax
import jax.numpy as jnp

CAMPAIGN_DIR = "experiments/campaigns"

# default graph-level mode set for the measured and analytic probes
DEFAULT_GRAPH_MODES = ("fp_add32", "mxu_fma128", "vmem_ld", "hbm_stream")


def build_step_region(arch: str, kind: str, modes: Sequence[str], *,
                      seq: int, batch: int):
    """The graph-level model-step RegionTarget the measured probe and
    "step" fleet TargetSpecs share: reduced (smoke) config, host backend,
    noise injected around the whole jitted train/decode step."""
    from repro.configs import get_smoke_config
    from repro.configs.base import ShapeConfig
    from repro.core import step_region
    from repro.core.noise import NoiseScale, make_modes
    from repro.models.model import build

    registry = make_modes(NoiseScale(hbm_mib=32, chase_len=1 << 20))
    unknown = [m for m in modes if m not in registry]
    if unknown:
        raise SystemExit(f"unknown mode(s) {unknown}; available: "
                         f"{', '.join(sorted(registry))}")

    cfg = get_smoke_config(arch)
    api = build(cfg)
    params = api.init(jax.random.PRNGKey(0))
    shape = ShapeConfig("probe", kind, seq, batch)

    if kind == "train":
        batch_data = api.dummy_batch(shape)

        def step(p, b):
            return api.loss(p, b)[0]
        args = (params, batch_data)
    else:
        cache = api.decode_init(params, {"tokens": jnp.zeros((batch, 1),
                                                             jnp.int32),
                                         "max_seq": seq})
        toks = jnp.zeros((batch, 1), jnp.int32)

        def step(p, c, t):
            return api.decode_step(p, c, t, jnp.int32(seq // 2))[0]
        args = (params, cache, toks)

    region_name = f"{cfg.name}_{kind}_s{seq}_b{batch}"
    return step_region(region_name, step, args,
                       {m: registry[m] for m in modes})


def _run_adhoc(spec, *, reps: int, store: str | None, fresh: bool,
               workers: int, compile_once: bool,
               shard: Optional[tuple[int, int]], expect_no_measure: bool,
               header: str, audit: str = "gate",
               quality: str = "gate") -> None:
    """Build a one-target SweepPlan from CLI flags and execute it through
    the fleet worker — the campaign tail (store naming, shard dispatch,
    reporting) lives behind that API now."""
    from repro.fleet.executor import run_worker
    from repro.fleet.plan import SweepPlan

    plan = SweepPlan(name=header, store=store or "", targets=[spec],
                     reps=reps, shards=(shard[1] if shard else 1),
                     workers=workers, compile_once=compile_once,
                     backend="auto")
    if not plan.store:
        first = plan.resolve()[0][1][0]
        plan.store = os.path.join(CAMPAIGN_DIR, f"{first.name}.jsonl")
    run_worker(plan, index=(shard[0] if shard else None),
               count=(shard[1] if shard else None), fresh=fresh,
               expect_no_measure=expect_no_measure, header=header,
               audit=audit, quality=quality)


def measured_probe(arch: str, kind: str, modes: list[str], *, seq: int,
                   batch: int, reps: int, store: str | None = None,
                   fresh: bool = False, workers: int = 1,
                   compile_once: bool = True,
                   shard: Optional[tuple[int, int]] = None,
                   expect_no_measure: bool = False,
                   audit: str = "gate", quality: str = "gate") -> None:
    """Measured graph-level probe of one model step (smoke config, host
    backend): builds a one-target SweepPlan from the flags and runs it
    through the fleet worker's campaign tail."""
    from repro.core.noise import make_modes

    unknown = [m for m in modes if m not in make_modes()]
    if unknown:
        raise SystemExit(f"unknown mode(s) {unknown}; available: "
                         f"{', '.join(sorted(make_modes()))}")
    from repro.fleet.plan import TargetSpec

    spec = TargetSpec("step", tuple(modes),
                      {"arch": arch, "kind": kind, "seq": seq,
                       "batch": batch})
    _run_adhoc(spec, reps=reps, store=store, fresh=fresh, workers=workers,
               compile_once=compile_once, shard=shard,
               expect_no_measure=expect_no_measure, audit=audit,
               quality=quality,
               header=f"measured probe: {arch} {kind} seq={seq} "
                      f"batch={batch}")


def serve_probe(arch: str, modes: list[str], *, slots: int, prompt: int,
                max_new: int, reps: int, store: str | None = None,
                fresh: bool = False, workers: int = 1,
                compile_once: bool = True,
                shard: Optional[tuple[int, int]] = None,
                expect_no_measure: bool = False,
                audit: str = "gate", quality: str = "gate") -> None:
    """Measured probe of the paged serving engine (smoke config, host
    backend): one plan, TWO regions — the engine's batched prefill and its
    decode tick (``repro.serve.load.build_serve_regions``) — so prefill and
    decode classify separately under the same campaign store."""
    from repro.core.noise import make_modes

    unknown = [m for m in modes if m not in make_modes()]
    if unknown:
        raise SystemExit(f"unknown mode(s) {unknown}; available: "
                         f"{', '.join(sorted(make_modes()))}")
    from repro.fleet.plan import TargetSpec

    spec = TargetSpec("serve", tuple(modes),
                      {"arch": arch, "slots": slots, "prompt": prompt,
                       "max_new": max_new})
    _run_adhoc(spec, reps=reps, store=store, fresh=fresh, workers=workers,
               compile_once=compile_once, shard=shard,
               expect_no_measure=expect_no_measure, audit=audit,
               quality=quality,
               header=f"serve probe: {arch} slots={slots} prompt={prompt}")


def pallas_probe(kernel: str, modes: Optional[list[str]], *, reps: int,
                 n: Optional[int] = None, store: str | None = None,
                 fresh: bool = False, workers: int = 1,
                 compile_once: bool = True,
                 shard: Optional[tuple[int, int]] = None,
                 expect_no_measure: bool = False,
                 audit: str = "gate", quality: str = "gate") -> None:
    """Run the paper's methodology against a real Pallas kernel (interpret
    mode off-TPU). The sweep rides the compile-once runtime-k path: ≤2
    Pallas executables per (kernel, mode)."""
    from repro.kernels.region import KERNEL_MODES, SIZE_DEFAULT, validate_size

    if kernel not in KERNEL_MODES:
        raise SystemExit(f"unknown pallas kernel {kernel!r}; one of "
                         f"{', '.join(sorted(KERNEL_MODES))}")
    modes = modes or list(KERNEL_MODES[kernel])
    unknown = [m for m in modes if m not in KERNEL_MODES[kernel]]
    if unknown:
        raise SystemExit(f"kernel {kernel!r} supports modes "
                         f"{KERNEL_MODES[kernel]}, not {unknown}")
    if n is not None:
        try:
            validate_size(kernel, n)
        except ValueError as e:
            raise SystemExit(f"--pallas-n: {e}")
    from repro.fleet.plan import TargetSpec

    spec = TargetSpec("pallas", tuple(modes),
                      {"kernel": kernel,
                       "sizes": [n if n is not None else
                                 SIZE_DEFAULT[kernel]]})
    _run_adhoc(spec, reps=reps, store=store, fresh=fresh, workers=workers,
               compile_once=compile_once, shard=shard,
               expect_no_measure=expect_no_measure, audit=audit,
               quality=quality, header=f"pallas probe: {kernel}")


def plan_probe(plan_path: str, *, shard: Optional[tuple[int, int]],
               fresh: bool, expect_no_measure: bool,
               audit: str = "gate", quality: str = "gate") -> None:
    """The fleet worker entry: execute (a shard of) a saved SweepPlan."""
    from repro.fleet.executor import FleetError, run_worker
    from repro.fleet.plan import PlanError, SweepPlan

    try:
        plan = SweepPlan.load(plan_path)
    except (OSError, ValueError) as e:       # PlanError is a ValueError
        raise SystemExit(f"--plan {plan_path}: {e}")
    try:
        run_worker(plan, index=(shard[0] if shard else None),
                   count=(shard[1] if shard else None), fresh=fresh,
                   expect_no_measure=expect_no_measure, audit=audit,
                   quality=quality)
    except (FleetError, PlanError) as e:
        raise SystemExit(str(e))


def analytic_probe(arch: str, shape_name: str, dryrun_dir: str,
                   modes: list[str], *, tol: float, store: str | None = None,
                   fresh: bool = False, expect_no_measure: bool = False
                   ) -> None:
    """Analytic probe of one (arch, shape) dry-run cell: push its roofline
    terms through the saturation model as a resumable prediction campaign
    (``pred`` records replay byte-identically on re-run)."""
    from repro.configs import TPU_V5E, canonical
    from repro.core import AnalyticCampaign, StepTerms, classify
    from repro.core.analytic import pattern_deltas
    from repro.core.noise import make_modes
    from repro.fleet.executor import finish_stats

    cell = os.path.join(dryrun_dir, f"{canonical(arch)}_{shape_name}.json")
    with open(cell) as f:
        rec = json.load(f)
    if rec.get("status") != "ok":
        raise SystemExit(f"dry-run cell {cell} status={rec.get('status')}")
    r = rec["roofline"]
    terms = StepTerms(compute=r["t_compute"], memory=r["t_memory"],
                      ici=r["t_ici"])
    registry = make_modes()
    region_name = f"{canonical(arch)}_{shape_name}"
    store = store or os.path.join(CAMPAIGN_DIR, f"{region_name}_pred.jsonl")
    if fresh and os.path.exists(store):
        os.unlink(store)
    camp = AnalyticCampaign(store, hw=TPU_V5E, tol=tol, k_max=1 << 44)
    print(f"== analytic probe: {arch} {shape_name} [{rec['mesh']}] "
          f"(terms from dry-run: Tc={terms.compute*1e3:.2f}ms "
          f"Tm={terms.memory*1e3:.2f}ms Ti={terms.ici*1e3:.2f}ms, "
          f"dominant={r['dominant']}; campaign store: {store})")
    t0 = terms.bound()

    def classify_fracs(results) -> "object":
        # absorbed-work fraction: what share of the step time each mode's
        # noise occupies before detection — the step-scale-free absorption
        # (bound resource ~= tol; slack resources >> tol)
        fracs = {}
        for m, res in results.items():
            delta = max(pattern_deltas(registry[m], TPU_V5E).values())
            fracs[m] = 100.0 * res.fit.k1 * delta / t0
        return classify(fracs, low=2.0 * 100 * tol, high=6.0 * 100 * tol)

    rep = camp.characterize(region_name, terms,
                            {m: registry[m] for m in modes},
                            classify_fn=classify_fracs)
    for m, res in rep.results.items():
        delta = max(pattern_deltas(registry[m], TPU_V5E).values())
        frac = 100.0 * res.fit.k1 * delta / t0
        print(f"  {m:14s} Abs^raw={res.fit.k1:14.0f} patterns "
              f"(~{frac:6.1f}% of step absorbable)")
    print(f"  => {rep.bottleneck}")
    finish_stats(camp.stats, expect_no_measure)


def _parse_shard(text: str) -> tuple[int, int]:
    try:
        idx, cnt = (int(p) for p in text.split("/"))
    except ValueError:
        raise SystemExit(f"--shard wants I/N (e.g. 0/2), got {text!r}")
    if not (0 <= idx < cnt):
        raise SystemExit(f"--shard index {idx} not in [0, {cnt})")
    return idx, cnt


def build_parser() -> argparse.ArgumentParser:
    """The probe CLI's argparse tree (exposed for help/doc tests)."""
    ap = argparse.ArgumentParser(
        prog="python -m repro.launch.probe",
        description="noise-injection bottleneck probe (measured, analytic, "
                    "pallas-kernel, and fleet-worker modes)")
    ap.add_argument("--arch", default=None,
                    help="model architecture (required unless --pallas or "
                         "--plan)")
    ap.add_argument("--smoke", action="store_true",
                    help="reduced smoke config (measured mode always uses "
                         "it; flag kept for explicitness)")
    ap.add_argument("--kind", default="train", choices=("train", "decode"),
                    help="which model step to probe")
    ap.add_argument("--shape", default="train_4k",
                    help="dry-run shape cell to read under --analytic")
    ap.add_argument("--analytic", action="store_true",
                    help="predict absorption from the dry-run roofline "
                         "terms instead of measuring")
    ap.add_argument("--plan", default=None, metavar="PLAN.json",
                    help="execute a repro.fleet SweepPlan: with --shard I/N "
                         "measure that slice into its worker store (the "
                         "fleet worker entry; launchers hand it the "
                         "REPRO_FLEET_EXPECT_DIGEST/REPRO_FLEET_HOST "
                         "handshake env); without, run the whole plan "
                         "in-process, classify, and write the report")
    ap.add_argument("--serve", action="store_true",
                    help="probe the paged serving engine instead of a bare "
                         "model step: two regions (batched prefill + decode "
                         "tick) under one campaign; --seq is the prompt "
                         "length, --batch the slot count")
    ap.add_argument("--max-new", type=int, default=8,
                    help="decode budget per request of the probed serve "
                         "workload (--serve)")
    ap.add_argument("--pallas", default=None,
                    metavar="{matmul,spmxv,attention,probe}",
                    help="probe a Pallas kernel region instead of a model "
                         "step (interpret mode off-TPU; modes default to "
                         "the kernel's fp/mxu/vmem set)")
    ap.add_argument("--pallas-n", type=int, default=None,
                    help="kernel size knob (rows for matmul/spmxv, seq for "
                         "attention, grid steps for probe)")
    ap.add_argument("--dryrun-dir", default="experiments/dryrun/16x16",
                    help="where the dry-run artifact cells live "
                         "(--analytic)")
    ap.add_argument("--modes", default=None,
                    help="noise modes (default: "
                         f"{','.join(DEFAULT_GRAPH_MODES)}, or the "
                         "kernel's fp/mxu/vmem set under --pallas)")
    ap.add_argument("--seq", type=int, default=128,
                    help="sequence length of the probed step")
    ap.add_argument("--batch", type=int, default=4,
                    help="batch size of the probed step")
    ap.add_argument("--reps", type=int, default=3,
                    help="timing repetitions per measured point")
    ap.add_argument("--tol", type=float, default=0.05,
                    help="absorption-fit detection tolerance (--analytic)")
    ap.add_argument("--store", default=None,
                    help="campaign JSONL path (default: derived under "
                         f"{CAMPAIGN_DIR}/)")
    ap.add_argument("--fresh", action="store_true",
                    help="discard any existing campaign store first")
    ap.add_argument("--workers", type=int, default=1,
                    help="fan independent mode sweeps over N threads")
    ap.add_argument("--shard", default=None, metavar="I/N",
                    help="measure only worker I's slice of the grid into a "
                         "per-worker store (multi-host fan-out; N must "
                         "match the plan's shards under --plan)")
    ap.add_argument("--expect-no-measure", action="store_true",
                    help="exit non-zero if any fresh measurement was needed "
                         "(assert a merged/complete store replays fully)")
    ap.add_argument("--no-compile-once", action="store_true",
                    help="force the trace-per-k fallback sweep path")
    ap.add_argument("--audit", default="gate",
                    choices=("gate", "warn", "off"),
                    help="static noise-audit policy for whole-plan/ad-hoc "
                         "runs (shards never audit): gate (default) refuses "
                         "statically-dead pairs before measuring, warn "
                         "measures anyway, off skips the audit")
    ap.add_argument("--quality", default="gate",
                    choices=("gate", "warn", "off"),
                    help="runtime measurement-quality policy for whole-plan/"
                         "ad-hoc runs: gate (default) refuses a majority-"
                         "quarantined classification, warn reports it, off "
                         "attaches no quality evidence (only plans that "
                         "declare a quality policy guard their measurements)")
    return ap


def main(argv: Optional[Sequence[str]] = None) -> None:
    """CLI entry: route the flags to the measured / analytic / pallas /
    fleet-worker probe path."""
    args = build_parser().parse_args(argv)

    modes = ([m.strip() for m in args.modes.split(",") if m.strip()]
             if args.modes else None)
    shard = _parse_shard(args.shard) if args.shard is not None else None
    if args.plan is not None:
        # the plan overrides ALL of these; silently ignoring one would let a
        # user believe they changed the measurement settings
        overridden = [flag for flag, given in (
            ("--arch", args.arch), ("--pallas", args.pallas),
            ("--serve", args.serve),
            ("--analytic", args.analytic), ("--modes", modes),
            ("--store", args.store), ("--reps", args.reps != 3),
            ("--workers", args.workers != 1),
            ("--no-compile-once", args.no_compile_once),
            ("--kind", args.kind != "train"), ("--seq", args.seq != 128),
            ("--batch", args.batch != 4)) if given]
        if overridden:
            raise SystemExit("--plan carries its own targets, modes and "
                             "settings; drop the conflicting flag(s): "
                             + ", ".join(overridden))
        plan_probe(args.plan, shard=shard, fresh=args.fresh,
                   expect_no_measure=args.expect_no_measure,
                   audit=args.audit, quality=args.quality)
        return
    if args.pallas is not None:
        if args.analytic or args.serve:
            raise SystemExit("--pallas excludes --analytic and --serve")
        pallas_probe(args.pallas, modes, reps=args.reps, n=args.pallas_n,
                     store=args.store, fresh=args.fresh,
                     workers=args.workers,
                     compile_once=not args.no_compile_once, shard=shard,
                     expect_no_measure=args.expect_no_measure,
                     audit=args.audit, quality=args.quality)
        return
    if args.arch is None:
        raise SystemExit("--arch is required unless --pallas or --plan "
                         "is given")
    if args.serve:
        if args.analytic:
            raise SystemExit("--serve and --analytic are mutually exclusive")
        serve_probe(args.arch, modes or list(DEFAULT_GRAPH_MODES),
                    slots=args.batch, prompt=args.seq, max_new=args.max_new,
                    reps=args.reps, store=args.store, fresh=args.fresh,
                    workers=args.workers,
                    compile_once=not args.no_compile_once, shard=shard,
                    expect_no_measure=args.expect_no_measure,
                    audit=args.audit, quality=args.quality)
        return
    if args.analytic:
        if shard is not None:
            raise SystemExit("--shard applies to measured mode only "
                             "(predictions are too cheap to fan out)")
        analytic_probe(args.arch, args.shape, args.dryrun_dir,
                       modes or list(DEFAULT_GRAPH_MODES),
                       tol=args.tol, store=args.store, fresh=args.fresh,
                       expect_no_measure=args.expect_no_measure)
    else:
        measured_probe(args.arch, args.kind,
                       modes or list(DEFAULT_GRAPH_MODES),
                       seq=args.seq, batch=args.batch, reps=args.reps,
                       store=args.store, fresh=args.fresh,
                       workers=args.workers,
                       compile_once=not args.no_compile_once,
                       shard=shard,
                       expect_no_measure=args.expect_no_measure,
                       audit=args.audit, quality=args.quality)


if __name__ == "__main__":
    main()

"""Noise-injection bottleneck probe — the paper's tool applied to this
framework's own train/serve steps and to the Pallas kernel layer.

Measured mode (default; reduced config, host backend) runs as a resumable
CAMPAIGN: every (mode, k, t) point persists to a JSONL store under
``experiments/campaigns/`` and re-running skips everything already measured.
The sweep itself uses the controller's compile-once path (one runtime-k
executable per mode instead of one per sweep point):

    PYTHONPATH=src python -m repro.launch.probe --arch gemma-2b --smoke \
        --kind train --modes fp_add32,vmem_ld,hbm_stream \
        [--store PATH] [--fresh] [--workers N] [--no-compile-once]

Pallas mode probes one of the real kernels (matmul / spmxv / attention /
probe; interpret mode off-TPU) through the SAME campaign machinery — the
noise quantity is a runtime operand of the kernel itself, so the whole
sweep compiles ≤2 Pallas executables per mode:

    PYTHONPATH=src python -m repro.launch.probe --pallas spmxv \
        [--modes fp,vmem] [--store PATH] [--expect-no-measure]

Multi-host fan-out: give each host/process ``--shard I/N`` — it measures a
disjoint slice of the mode grid into its own per-worker store (the base
store name with a ``.wIofN`` suffix). When all shards finish, merge and
replay:

    python -m repro.core.campaign merge STORE STORE.w0of2.jsonl STORE.w1of2.jsonl
    python -m repro.launch.probe ... --store STORE --expect-no-measure

``--expect-no-measure`` turns "the store fully covers this probe" into an
exit code, so scripts and CI can assert the round-trip measured nothing.

Analytic mode (full config, TPU v5e target, reads the dry-run artifact) runs
through the SAME campaign machinery — predictions persist as ``pred``
records (curve + fit + HardwareConfig/terms/settings) and replay on re-run:

    PYTHONPATH=src python -m repro.launch.probe --arch gemma-2b \
        --shape train_4k --analytic [--dryrun-dir experiments/dryrun/16x16] \
        [--store PATH] [--fresh]

All paths report Abs^raw per mode + the bottleneck classification; measured
modes also verify the payload statically (surviving noise ops in optimized
HLO, or the exact nacc oracle for Pallas kernels)."""
from __future__ import annotations

import argparse
import json
import os
from typing import Optional

import jax
import jax.numpy as jnp

CAMPAIGN_DIR = "experiments/campaigns"

# default graph-level mode set for the measured and analytic probes
DEFAULT_GRAPH_MODES = ("fp_add32", "mxu_fma128", "vmem_ld", "hbm_stream")


def _finish(stats, expect_no_measure: bool) -> None:
    print(f"  [{stats.measured} points measured, "
          f"{stats.cached} replayed from store]")
    if expect_no_measure and stats.measured:
        raise SystemExit(
            f"--expect-no-measure: store was incomplete, {stats.measured} "
            "fresh measurements were needed")


def _campaign_probe(region, modes: list[str], *, reps: int,
                    store: str | None, fresh: bool, workers: int,
                    compile_once: bool, shard: Optional[tuple[int, int]],
                    expect_no_measure: bool, header: str) -> None:
    """The shared campaign tail: store naming, shard dispatch, reporting."""
    from repro.core import Campaign, Controller, worker_store

    store = store or os.path.join(CAMPAIGN_DIR, f"{region.name}.jsonl")
    if shard is not None:
        store = worker_store(store, *shard)
    if fresh and os.path.exists(store):
        os.unlink(store)
    ctl = Controller(reps=reps, compile_once=compile_once)
    camp = Campaign(store, ctl, workers=workers)

    if shard is not None:
        idx, cnt = shard
        print(f"== {header} [shard {idx}/{cnt}] (worker store: {store})")
        res = camp.measure_shard([region], modes, index=idx, count=cnt)
        for (_, m), r in sorted(res.items()):
            print(f"  {m:14s} Abs^raw={r.fit.k1:7.1f} "
                  f"t0={r.fit.t0*1e3:8.2f}ms")
        if not res:
            print(f"  (no pairs land on shard {idx} of {cnt})")
        print("  [classification happens after `python -m repro.core.campaign"
              " merge`; a shard sees only its slice]")
        _finish(camp.stats, expect_no_measure)
        return

    print(f"== {header} (campaign store: {store})")
    rep = camp.characterize(region, modes)
    for m, r in rep.results.items():
        inj = r.injection
        pay = (f"payload={inj.payload}/{inj.expected} overhead={inj.overhead}"
               if inj else "payload=n/a")
        print(f"  {m:14s} Abs^raw={r.fit.k1:7.1f} t0={r.fit.t0*1e3:8.2f}ms "
              f"slope={r.fit.slope*1e6:9.2f}us/pat {pay}")
    print(f"  => {rep.bottleneck}")
    _finish(camp.stats, expect_no_measure)


def measured_probe(arch: str, kind: str, modes: list[str], *, seq: int,
                   batch: int, reps: int, store: str | None = None,
                   fresh: bool = False, workers: int = 1,
                   compile_once: bool = True,
                   shard: Optional[tuple[int, int]] = None,
                   expect_no_measure: bool = False) -> None:
    from repro.configs import get_smoke_config
    from repro.configs.base import ShapeConfig
    from repro.core import step_region
    from repro.core.noise import NoiseScale, make_modes
    from repro.models.model import build

    registry = make_modes(NoiseScale(hbm_mib=32, chase_len=1 << 20))
    unknown = [m for m in modes if m not in registry]
    if unknown:
        raise SystemExit(f"unknown mode(s) {unknown}; available: "
                         f"{', '.join(sorted(registry))}")

    cfg = get_smoke_config(arch)
    api = build(cfg)
    params = api.init(jax.random.PRNGKey(0))
    shape = ShapeConfig("probe", kind, seq, batch)

    if kind == "train":
        batch_data = api.dummy_batch(shape)

        def step(p, b):
            return api.loss(p, b)[0]
        args = (params, batch_data)
    else:
        cache = api.decode_init(params, {"tokens": jnp.zeros((batch, 1),
                                                             jnp.int32),
                                         "max_seq": seq})
        toks = jnp.zeros((batch, 1), jnp.int32)

        def step(p, c, t):
            return api.decode_step(p, c, t, jnp.int32(seq // 2))[0]
        args = (params, cache, toks)

    region_name = f"{cfg.name}_{kind}_s{seq}_b{batch}"
    region = step_region(region_name, step, args,
                         {m: registry[m] for m in modes})
    _campaign_probe(region, modes, reps=reps, store=store, fresh=fresh,
                    workers=workers, compile_once=compile_once, shard=shard,
                    expect_no_measure=expect_no_measure,
                    header=f"measured probe: {cfg.name} {kind} seq={seq} "
                           f"batch={batch}")


# per-kernel meaning of the --pallas-n size knob, and the block size it must
# be a multiple of (sizes below one block are allowed: the block shrinks)
_PALLAS_SIZE_KW = {"matmul": "n", "spmxv": "n", "attention": "seq",
                   "probe": "n_steps"}
_PALLAS_ALIGN = {"matmul": 128, "spmxv": 128, "attention": 64, "probe": 1}


def pallas_probe(kernel: str, modes: Optional[list[str]], *, reps: int,
                 n: Optional[int] = None, store: str | None = None,
                 fresh: bool = False, workers: int = 1,
                 compile_once: bool = True,
                 shard: Optional[tuple[int, int]] = None,
                 expect_no_measure: bool = False) -> None:
    """Run the paper's methodology against a real Pallas kernel (interpret
    mode off-TPU). The sweep rides the compile-once runtime-k path: ≤2
    Pallas executables per (kernel, mode)."""
    from repro.kernels.region import KERNEL_MODES, pallas_region

    if kernel not in KERNEL_MODES:
        raise SystemExit(f"unknown pallas kernel {kernel!r}; one of "
                         f"{', '.join(sorted(KERNEL_MODES))}")
    modes = modes or list(KERNEL_MODES[kernel])
    unknown = [m for m in modes if m not in KERNEL_MODES[kernel]]
    if unknown:
        raise SystemExit(f"kernel {kernel!r} supports modes "
                         f"{KERNEL_MODES[kernel]}, not {unknown}")
    if n is not None:
        align = _PALLAS_ALIGN[kernel]
        if n < 1:
            raise SystemExit(f"--pallas-n must be positive; got {n}")
        # blocked kernels: noise patterns read 8-row groups, and sizes past
        # one block must tile evenly ('probe' counts grid steps — any n ok)
        if align > 1 and (n < 8 or (n > align and n % align)):
            raise SystemExit(
                f"--pallas-n for {kernel!r} must be >= 8 and a multiple of "
                f"its {align}-wide block (or smaller than one block); "
                f"got {n}")
    sizes = {} if n is None else {_PALLAS_SIZE_KW[kernel]: n}
    region = pallas_region(kernel, **sizes)
    _campaign_probe(region, modes, reps=reps, store=store, fresh=fresh,
                    workers=workers, compile_once=compile_once, shard=shard,
                    expect_no_measure=expect_no_measure,
                    header=f"pallas probe: {region.name}")


def analytic_probe(arch: str, shape_name: str, dryrun_dir: str,
                   modes: list[str], *, tol: float, store: str | None = None,
                   fresh: bool = False, expect_no_measure: bool = False
                   ) -> None:
    from repro.configs import TPU_V5E, canonical
    from repro.core import AnalyticCampaign, StepTerms, classify
    from repro.core.analytic import pattern_deltas
    from repro.core.noise import make_modes

    cell = os.path.join(dryrun_dir, f"{canonical(arch)}_{shape_name}.json")
    with open(cell) as f:
        rec = json.load(f)
    if rec.get("status") != "ok":
        raise SystemExit(f"dry-run cell {cell} status={rec.get('status')}")
    r = rec["roofline"]
    terms = StepTerms(compute=r["t_compute"], memory=r["t_memory"],
                      ici=r["t_ici"])
    registry = make_modes()
    region_name = f"{canonical(arch)}_{shape_name}"
    store = store or os.path.join(CAMPAIGN_DIR, f"{region_name}_pred.jsonl")
    if fresh and os.path.exists(store):
        os.unlink(store)
    camp = AnalyticCampaign(store, hw=TPU_V5E, tol=tol, k_max=1 << 44)
    print(f"== analytic probe: {arch} {shape_name} [{rec['mesh']}] "
          f"(terms from dry-run: Tc={terms.compute*1e3:.2f}ms "
          f"Tm={terms.memory*1e3:.2f}ms Ti={terms.ici*1e3:.2f}ms, "
          f"dominant={r['dominant']}; campaign store: {store})")
    t0 = terms.bound()

    def classify_fracs(results) -> "object":
        # absorbed-work fraction: what share of the step time each mode's
        # noise occupies before detection — the step-scale-free absorption
        # (bound resource ~= tol; slack resources >> tol)
        fracs = {}
        for m, res in results.items():
            delta = max(pattern_deltas(registry[m], TPU_V5E).values())
            fracs[m] = 100.0 * res.fit.k1 * delta / t0
        return classify(fracs, low=2.0 * 100 * tol, high=6.0 * 100 * tol)

    rep = camp.characterize(region_name, terms,
                            {m: registry[m] for m in modes},
                            classify_fn=classify_fracs)
    for m, res in rep.results.items():
        delta = max(pattern_deltas(registry[m], TPU_V5E).values())
        frac = 100.0 * res.fit.k1 * delta / t0
        print(f"  {m:14s} Abs^raw={res.fit.k1:14.0f} patterns "
              f"(~{frac:6.1f}% of step absorbable)")
    print(f"  => {rep.bottleneck}")
    _finish(camp.stats, expect_no_measure)


def _parse_shard(text: str) -> tuple[int, int]:
    try:
        idx, cnt = (int(p) for p in text.split("/"))
    except ValueError:
        raise SystemExit(f"--shard wants I/N (e.g. 0/2), got {text!r}")
    if not (0 <= idx < cnt):
        raise SystemExit(f"--shard index {idx} not in [0, {cnt})")
    return idx, cnt


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None,
                    help="model architecture (required unless --pallas)")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--kind", default="train", choices=("train", "decode"))
    ap.add_argument("--shape", default="train_4k")
    ap.add_argument("--analytic", action="store_true")
    ap.add_argument("--pallas", default=None,
                    metavar="{matmul,spmxv,attention,probe}",
                    help="probe a Pallas kernel region instead of a model "
                         "step (interpret mode off-TPU; modes default to "
                         "the kernel's fp/mxu/vmem set)")
    ap.add_argument("--pallas-n", type=int, default=None,
                    help="kernel size knob (rows for matmul/spmxv, seq for "
                         "attention, grid steps for probe)")
    ap.add_argument("--dryrun-dir", default="experiments/dryrun/16x16")
    ap.add_argument("--modes", default=None,
                    help="noise modes (default: "
                         f"{','.join(DEFAULT_GRAPH_MODES)}, or the "
                         "kernel's fp/mxu/vmem set under --pallas)")
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--reps", type=int, default=3)
    ap.add_argument("--tol", type=float, default=0.05)
    ap.add_argument("--store", default=None,
                    help="campaign JSONL path (default: derived under "
                         f"{CAMPAIGN_DIR}/)")
    ap.add_argument("--fresh", action="store_true",
                    help="discard any existing campaign store first")
    ap.add_argument("--workers", type=int, default=1,
                    help="fan independent mode sweeps over N threads")
    ap.add_argument("--shard", default=None, metavar="I/N",
                    help="measure only worker I's slice of the mode grid "
                         "into a per-worker store (multi-host fan-out; "
                         "merge the worker stores afterwards)")
    ap.add_argument("--expect-no-measure", action="store_true",
                    help="exit non-zero if any fresh measurement was needed "
                         "(assert a merged/complete store replays fully)")
    ap.add_argument("--no-compile-once", action="store_true",
                    help="force the trace-per-k fallback sweep path")
    args = ap.parse_args()

    modes = ([m.strip() for m in args.modes.split(",") if m.strip()]
             if args.modes else None)
    shard = _parse_shard(args.shard) if args.shard is not None else None
    if args.pallas is not None:
        if args.analytic:
            raise SystemExit("--pallas and --analytic are mutually exclusive")
        pallas_probe(args.pallas, modes, reps=args.reps, n=args.pallas_n,
                     store=args.store, fresh=args.fresh,
                     workers=args.workers,
                     compile_once=not args.no_compile_once, shard=shard,
                     expect_no_measure=args.expect_no_measure)
        return
    if args.arch is None:
        ap.error("--arch is required unless --pallas is given")
    if args.analytic:
        if shard is not None:
            raise SystemExit("--shard applies to measured mode only "
                             "(predictions are too cheap to fan out)")
        analytic_probe(args.arch, args.shape, args.dryrun_dir,
                       modes or list(DEFAULT_GRAPH_MODES),
                       tol=args.tol, store=args.store, fresh=args.fresh,
                       expect_no_measure=args.expect_no_measure)
    else:
        measured_probe(args.arch, args.kind,
                       modes or list(DEFAULT_GRAPH_MODES),
                       seq=args.seq, batch=args.batch, reps=args.reps,
                       store=args.store, fresh=args.fresh,
                       workers=args.workers,
                       compile_once=not args.no_compile_once,
                       shard=shard,
                       expect_no_measure=args.expect_no_measure)


if __name__ == "__main__":
    main()

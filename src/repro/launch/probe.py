"""Noise-injection bottleneck probe — the paper's tool applied to this
framework's own train/serve steps.

Measured mode (default; reduced config, host backend) runs as a resumable
CAMPAIGN: every (mode, k, t) point persists to a JSONL store under
``experiments/campaigns/`` and re-running skips everything already measured.
The sweep itself uses the controller's compile-once path (one runtime-k
executable per mode instead of one per sweep point):

    PYTHONPATH=src python -m repro.launch.probe --arch gemma-2b --smoke \
        --kind train --modes fp_add32,vmem_ld,hbm_stream \
        [--store PATH] [--fresh] [--workers N] [--no-compile-once]

Analytic mode (full config, TPU v5e target, reads the dry-run artifact):
    PYTHONPATH=src python -m repro.launch.probe --arch gemma-2b \
        --shape train_4k --analytic [--dryrun-dir experiments/dryrun/16x16]

Both report Abs^raw per mode + the bottleneck classification; measured mode
also verifies the payload statically (surviving noise ops in optimized HLO).
"""
from __future__ import annotations

import argparse
import json
import os

import jax
import jax.numpy as jnp

CAMPAIGN_DIR = "experiments/campaigns"


def measured_probe(arch: str, kind: str, modes: list[str], *, seq: int,
                   batch: int, reps: int, store: str | None = None,
                   fresh: bool = False, workers: int = 1,
                   compile_once: bool = True) -> None:
    from repro.configs import get_smoke_config
    from repro.configs.base import ShapeConfig
    from repro.core import Campaign, Controller, step_region
    from repro.core.noise import NoiseScale, make_modes
    from repro.models.model import build

    registry = make_modes(NoiseScale(hbm_mib=32, chase_len=1 << 20))
    unknown = [m for m in modes if m not in registry]
    if unknown:
        raise SystemExit(f"unknown mode(s) {unknown}; available: "
                         f"{', '.join(sorted(registry))}")

    cfg = get_smoke_config(arch)
    api = build(cfg)
    params = api.init(jax.random.PRNGKey(0))
    shape = ShapeConfig("probe", kind, seq, batch)

    if kind == "train":
        batch_data = api.dummy_batch(shape)

        def step(p, b):
            return api.loss(p, b)[0]
        args = (params, batch_data)
    else:
        cache = api.decode_init(params, {"tokens": jnp.zeros((batch, 1),
                                                             jnp.int32),
                                         "max_seq": seq})
        toks = jnp.zeros((batch, 1), jnp.int32)

        def step(p, c, t):
            return api.decode_step(p, c, t, jnp.int32(seq // 2))[0]
        args = (params, cache, toks)

    region_name = f"{cfg.name}_{kind}_s{seq}_b{batch}"
    region = step_region(region_name, step, args,
                         {m: registry[m] for m in modes})
    store = store or os.path.join(CAMPAIGN_DIR, f"{region_name}.jsonl")
    if fresh and os.path.exists(store):
        os.unlink(store)
    ctl = Controller(reps=reps, compile_once=compile_once)
    camp = Campaign(store, ctl, workers=workers)
    print(f"== measured probe: {cfg.name} {kind} seq={seq} batch={batch} "
          f"(campaign store: {store})")
    rep = camp.characterize(region, modes)
    for m, r in rep.results.items():
        inj = r.injection
        pay = (f"payload={inj.payload}/{inj.expected} overhead={inj.overhead}"
               if inj else "payload=n/a")
        print(f"  {m:14s} Abs^raw={r.fit.k1:7.1f} t0={r.fit.t0*1e3:8.2f}ms "
              f"slope={r.fit.slope*1e6:9.2f}us/pat {pay}")
    print(f"  => {rep.bottleneck}")
    print(f"  [{camp.stats.measured} points measured, "
          f"{camp.stats.cached} replayed from store]")


def analytic_probe(arch: str, shape_name: str, dryrun_dir: str,
                   modes: list[str], *, tol: float) -> None:
    from repro.configs import TPU_V5E, canonical
    from repro.core import StepTerms, classify, predict_absorption
    from repro.core.analytic import pattern_deltas
    from repro.core.noise import make_modes

    cell = os.path.join(dryrun_dir, f"{canonical(arch)}_{shape_name}.json")
    with open(cell) as f:
        rec = json.load(f)
    if rec.get("status") != "ok":
        raise SystemExit(f"dry-run cell {cell} status={rec.get('status')}")
    r = rec["roofline"]
    terms = StepTerms(compute=r["t_compute"], memory=r["t_memory"],
                      ici=r["t_ici"])
    registry = make_modes()
    fracs = {}
    print(f"== analytic probe: {arch} {shape_name} [{rec['mesh']}] "
          f"(terms from dry-run: Tc={terms.compute*1e3:.2f}ms "
          f"Tm={terms.memory*1e3:.2f}ms Ti={terms.ici*1e3:.2f}ms, "
          f"dominant={r['dominant']})")
    t0 = terms.bound()
    for m in modes:
        fit = predict_absorption(terms, registry[m], TPU_V5E, tol=tol,
                                 k_max=1 << 44)
        # absorbed-work fraction: what share of the step time this mode's
        # noise occupies before detection — the step-scale-free absorption
        # (bound resource ~= tol; slack resources >> tol)
        delta = max(pattern_deltas(registry[m], TPU_V5E).values())
        frac = 100.0 * fit.k1 * delta / t0
        fracs[m] = frac
        print(f"  {m:14s} Abs^raw={fit.k1:14.0f} patterns "
              f"(~{frac:6.1f}% of step absorbable)")
    print(f"  => {classify(fracs, low=2.0 * 100 * tol, high=6.0 * 100 * tol)}")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--kind", default="train", choices=("train", "decode"))
    ap.add_argument("--shape", default="train_4k")
    ap.add_argument("--analytic", action="store_true")
    ap.add_argument("--dryrun-dir", default="experiments/dryrun/16x16")
    ap.add_argument("--modes", default="fp_add32,mxu_fma128,vmem_ld,hbm_stream")
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--reps", type=int, default=3)
    ap.add_argument("--tol", type=float, default=0.05)
    ap.add_argument("--store", default=None,
                    help="campaign JSONL path (default: derived under "
                         f"{CAMPAIGN_DIR}/)")
    ap.add_argument("--fresh", action="store_true",
                    help="discard any existing campaign store first")
    ap.add_argument("--workers", type=int, default=1,
                    help="fan independent mode sweeps over N workers")
    ap.add_argument("--no-compile-once", action="store_true",
                    help="force the trace-per-k fallback sweep path")
    args = ap.parse_args()

    modes = [m.strip() for m in args.modes.split(",") if m.strip()]
    if args.analytic:
        analytic_probe(args.arch, args.shape, args.dryrun_dir, modes,
                       tol=args.tol)
    else:
        measured_probe(args.arch, args.kind, modes, seq=args.seq,
                       batch=args.batch, reps=args.reps, store=args.store,
                       fresh=args.fresh, workers=args.workers,
                       compile_once=not args.no_compile_once)


if __name__ == "__main__":
    main()

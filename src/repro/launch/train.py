"""End-to-end training launcher.

    PYTHONPATH=src python -m repro.launch.train --arch gemma-2b --smoke \
        --steps 200 --seq 128 --batch 16

Real execution (host backend). ``--smoke`` scales the architecture to its
reduced same-family config so a ~100M-class run finishes on CPU; on a TPU
slice the same launcher runs the full config under the production mesh
(``--mesh single|multi``). Fault tolerance: checkpoints land in --ckpt-dir;
rerunning with the same flags resumes from the latest checkpoint.
"""
from __future__ import annotations

import argparse
import dataclasses
import logging

import jax


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true",
                    help="reduced same-family config (CPU-runnable)")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--compress", default=None, choices=(None, "int8"))
    ap.add_argument("--mesh", default="none", choices=("none", "single", "multi"))
    ap.add_argument("--ckpt-dir", default="")
    ap.add_argument("--ckpt-every", type=int, default=0)
    ap.add_argument("--task", default="lcg", choices=("lcg", "uniform"))
    ap.add_argument("--log-every", type=int, default=10)
    args = ap.parse_args()

    logging.basicConfig(level=logging.INFO)

    from repro.configs import TrainConfig, get_config, get_smoke_config
    from repro.configs.base import ShapeConfig
    from repro.models.model import build
    from repro.data.pipeline import SyntheticPipeline
    from repro.train.trainer import Trainer
    from repro.ckpt import CheckpointManager
    from repro.launch.mesh import make_production_mesh

    cfg = get_smoke_config(args.arch) if args.smoke else get_config(args.arch)
    api = build(cfg)
    shape = ShapeConfig("cli", "train", args.seq, args.batch)
    tcfg = TrainConfig(lr=args.lr, warmup_steps=max(args.steps // 20, 1),
                       total_steps=args.steps, microbatches=args.microbatches,
                       ckpt_every=args.ckpt_every,
                       ckpt_dir=args.ckpt_dir or "/tmp/repro_train_ckpt")
    mesh = None
    if args.mesh != "none":
        mesh = make_production_mesh(multi_pod=args.mesh == "multi")

    pipe = SyntheticPipeline(cfg, shape, task=args.task)
    ckpt = CheckpointManager(tcfg.ckpt_dir) if args.ckpt_every else None
    trainer = Trainer(api, tcfg, mesh=mesh, compress=args.compress,
                      ckpt_manager=ckpt)

    n_params = sum(x.size for x in jax.tree.leaves(
        jax.eval_shape(api.init, jax.random.PRNGKey(0))))
    print(f"arch={cfg.name} params={n_params/1e6:.1f}M "
          f"tokens/step={args.batch * args.seq}")

    start = 0
    state = trainer.init_state()
    if ckpt is not None and ckpt.steps():
        restored, start = ckpt.restore_latest(like=state, mesh=mesh)
        if restored is not None:
            state = restored
            print(f"resumed from checkpoint step {start}")

    def run():
        nonlocal state
        state, hist = trainer.run(state, pipe, steps=args.steps,
                                  start_step=start)
        return hist

    hist = run()
    for h in hist:
        if h["step"] % args.log_every == 0 or h["step"] == args.steps - 1:
            print(f"step {h['step']:5d} loss={h['loss']:.4f} "
                  f"gnorm={h['grad_norm']:.3f} lr={h['lr']:.2e} "
                  f"wall={h['wall_s']*1e3:.0f}ms")
    print(f"final loss: {hist[-1]['loss']:.4f} "
          f"(first: {hist[0]['loss']:.4f})")


if __name__ == "__main__":
    main()

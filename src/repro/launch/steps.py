"""Abstract step builders for the multi-pod dry-run: for every
(arch × shape × mesh) cell, produce (fn, abstract_args, in_shardings) so that
``jax.jit(fn, in_shardings=...).lower(*args).compile()`` exercises the full
production program — train_step (loss+grad+AdamW) for train shapes,
forward-only for prefill, one-token decode against a seq_len KV/state cache
for decode shapes — without allocating anything.

Per-arch memory tuning lives in DRYRUN_TUNING (microbatches bound activation
memory; scan_group trades recompute for saved residuals on the deepest
models). Values were chosen by napkin math against v5e's 16 GiB and then
checked against compiled memory_analysis (EXPERIMENTS.md §Dry-run).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding
from jax.sharding import PartitionSpec as P

from repro.configs import SHAPES, TrainConfig, get_config, shape_applicable
from repro.configs.base import ModelConfig, ShapeConfig
from repro.models.model import ModelApi, build
from repro.parallel.sharding import resolve, resolve_tree
from repro.train.optimizer import adamw_init, opt_spec_like
from repro.train.trainer import TrainState, make_train_step

# (microbatches, scan_group) per arch for train_4k. Rationale: microbatch
# count M splits the 256-seq global batch into M accumulation steps; the
# per-chip saved residual is then ceil(B/M/dp)·S·D·2B per layer boundary.
DRYRUN_TUNING: dict[str, tuple[int, int]] = {
    "mixtral_8x22b": (16, 1),  # M=16: temp 13.0 GiB (fits v5e; §Perf iter 5)
    "qwen3_moe_30b_a3b": (8, 1),
    "mamba2_780m": (1, 1),
    "whisper_large_v3": (4, 1),
    "llava_next_34b": (16, 2),
    "minitron_4b": (8, 1),     # 256k vocab: bound the logits buffer
    "deepseek_coder_33b": (8, 2),
    "gemma_2b": (8, 1),        # 256k vocab

    "mistral_large_123b": (8, 2),
    "zamba2_1p2b": (1, 1),
}

# decode cache length: the shape's seq_len (the assignment: "one new token
# with a KV cache of seq_len").


def _sds(tree):
    return jax.tree.map(
        lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), tree)


def _shardings_for(tree_logical, tree_sds, mesh):
    spec = resolve_tree(tree_logical, tree_sds, mesh)
    return jax.tree.map(lambda s: NamedSharding(mesh, s), spec)


def _batch_shardings(batch_sds, mesh):
    def one(x):
        spec = resolve(("batch",) + (None,) * (len(x.shape) - 1), x.shape, mesh)
        return NamedSharding(mesh, spec)
    return jax.tree.map(one, batch_sds)


@dataclasses.dataclass
class CellProgram:
    fn: Callable
    args: tuple                 # ShapeDtypeStructs
    in_shardings: Any
    out_shardings: Any          # or None (let GSPMD choose)
    donate: tuple = ()
    kind: str = "train"


def train_cell(api: ModelApi, shape: ShapeConfig, mesh,
               *, microbatches: int, scan_group: int,
               compress: str | None = None,
               remat: str = "nothing") -> CellProgram:
    tcfg = TrainConfig(microbatches=microbatches, scan_group=scan_group,
                       remat=remat)
    step = make_train_step(api, tcfg, mesh=mesh, compress=compress)

    def _abstract_state(rng):
        params = api.init(rng)
        return TrainState(params=params, opt=adamw_init(params),
                          residuals=None)

    state_sds = jax.eval_shape(_abstract_state, jax.random.PRNGKey(0))

    batch_sds = api.input_specs(shape)
    pspec = api.param_spec()
    params_sh = _shardings_for(pspec, state_sds.params, mesh)
    opt_logical = opt_spec_like(pspec, use_master=state_sds.opt.master is not None)
    mu_sh = _shardings_for(opt_logical["mu"], state_sds.opt.mu, mesh)
    nu_sh = _shardings_for(opt_logical["nu"], state_sds.opt.nu, mesh)
    master_sh = (_shardings_for(opt_logical["master"], state_sds.opt.master, mesh)
                 if state_sds.opt.master is not None else None)
    from repro.train.optimizer import AdamWState
    state_sh = TrainState(
        params=params_sh,
        opt=AdamWState(step=NamedSharding(mesh, P()), mu=mu_sh, nu=nu_sh,
                       master=master_sh),
        residuals=None)
    batch_sh = _batch_shardings(batch_sds, mesh)
    return CellProgram(fn=step, args=(state_sds, batch_sds),
                       in_shardings=(state_sh, batch_sh),
                       out_shardings=(state_sh, None),
                       donate=(0,), kind="train")


def prefill_cell(api: ModelApi, shape: ShapeConfig, mesh) -> CellProgram:
    kw = {}
    if api.cfg.n_experts:
        sizes = dict(zip(mesh.axis_names, mesh.axis_sizes))
        dp = sizes.get("pod", 1) * sizes.get("data", 1)
        kw["n_groups"] = dp      # shard the MoE dispatch buffer (see trainer)

    def fwd(params, batch):
        logits, aux = api.forward(params, batch, **kw)
        del aux
        # serving prefill emits the next-token distribution for every seq
        return logits[:, -1, :]

    params_sds = jax.eval_shape(api.init, jax.random.PRNGKey(0))
    batch_sds = api.input_specs(shape)
    batch_sds.pop("labels", None)
    params_sh = _shardings_for(api.param_spec(), params_sds, mesh)
    batch_sh = _batch_shardings(batch_sds, mesh)
    return CellProgram(fn=fwd, args=(params_sds, batch_sds),
                       in_shardings=(params_sh, batch_sh),
                       out_shardings=None, kind="prefill")


def decode_cell(api: ModelApi, shape: ShapeConfig, mesh) -> CellProgram:
    B, S = shape.global_batch, shape.seq_len

    def serve_step(params, cache, tokens, pos):
        return api.decode_step(params, cache, tokens, pos)

    params_sds = jax.eval_shape(api.init, jax.random.PRNGKey(0))
    if api.cfg.family == "encdec":
        cache_sds = jax.eval_shape(
            lambda p: api.decode_init(
                p, {"frames": jnp.zeros((B, api.cfg.enc_frames,
                                         api.cfg.d_model),
                                        jnp.dtype(api.cfg.compute_dtype)),
                    "max_seq": S}),
            params_sds)
    else:
        cache_sds = jax.eval_shape(
            lambda p: api.decode_init(
                p, {"tokens": jnp.zeros((B, 1), jnp.int32), "max_seq": S}),
            params_sds)
    tokens_sds = jax.ShapeDtypeStruct((B, 1), jnp.int32)
    pos_sds = jax.ShapeDtypeStruct((), jnp.int32)

    params_sh = _shardings_for(api.param_spec(), params_sds, mesh)
    cache_sh = _shardings_for(api.cache_spec(), cache_sds, mesh)
    tokens_sh = NamedSharding(mesh, resolve(("batch", None), (B, 1), mesh))
    pos_sh = NamedSharding(mesh, P())
    return CellProgram(
        fn=serve_step, args=(params_sds, cache_sds, tokens_sds, pos_sds),
        in_shardings=(params_sh, cache_sh, tokens_sh, pos_sh),
        out_shardings=(None, cache_sh), donate=(1,), kind="decode")


def build_cell(arch: str, shape_name: str, mesh, *,
               compress: str | None = None,
               overrides: dict | None = None,
               remat: str = "nothing") -> CellProgram:
    cfg = get_config(arch)
    if overrides:
        cfg = dataclasses.replace(cfg, **overrides)
    shape = SHAPES[shape_name]
    ok, reason = shape_applicable(cfg, shape)
    if not ok:
        raise SkipCell(reason)
    api = build(cfg)
    if shape.kind == "train":
        m, g = DRYRUN_TUNING.get(arch, (1, 1))
        return train_cell(api, shape, mesh, microbatches=m, scan_group=g,
                          compress=compress, remat=remat)
    if shape.kind == "prefill":
        return prefill_cell(api, shape, mesh)
    return decode_cell(api, shape, mesh)


class SkipCell(Exception):
    pass

import os
os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "")
                           + " --xla_force_host_platform_device_count=512").strip()
# The two lines above MUST run before any jax import: jax locks the device
# count at first backend init. Everything below is ordinary.

_DOC = """Multi-pod dry-run: lower + compile every (architecture × input
shape) on the production meshes and extract memory / cost / roofline evidence.

    PYTHONPATH=src python -m repro.launch.dryrun --arch gemma-2b --shape train_4k
    PYTHONPATH=src python -m repro.launch.dryrun --all                 # 16x16
    PYTHONPATH=src python -m repro.launch.dryrun --all --multi-pod     # 2x16x16

Outputs one JSON per cell under experiments/dryrun/<mesh>/ with:
  memory_analysis  — per-chip argument/output/temp bytes (proves it fits)
  cost_analysis    — XLA flops/bytes (loop bodies counted once; see roofline)
  roofline         — trip-count-aware FLOPs / HBM-traffic / wire bytes and
                     the three terms in seconds (EXPERIMENTS.md §Roofline)
  collectives      — per-opcode wire-byte breakdown (the collective schedule)
"""

import argparse
import dataclasses
import json
import time
import traceback


def run_cell(arch: str, shape_name: str, *, multi_pod: bool,
             out_dir: str = "experiments/dryrun",
             compress: str | None = None,
             overrides: dict | None = None,
             remat: str = "nothing",
             tag: str = "", verbose: bool = True) -> dict:
    import jax

    from repro import compat
    from repro.configs import SHAPES, TPU_V5E, get_config, shape_applicable
    from repro.launch.mesh import make_production_mesh
    from repro.launch.steps import SkipCell, build_cell
    from repro.roofline import analyze_compiled_text

    mesh_name = "2x16x16" if multi_pod else "16x16"
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    cell_id = f"{arch}_{shape_name}{('_' + tag) if tag else ''}"
    record: dict = {"arch": arch, "shape": shape_name, "mesh": mesh_name,
                    "tag": tag}

    ok, reason = shape_applicable(cfg, shape)
    if not ok:
        record.update(status="skip", reason=reason)
        _write(out_dir, mesh_name, cell_id, record)
        if verbose:
            print(f"SKIP {cell_id} [{mesh_name}]: {reason}")
        return record

    mesh = make_production_mesh(multi_pod=multi_pod)
    t0 = time.time()
    try:
        with compat.set_mesh(mesh):  # sets the ambient mesh: logical-axis
            # sharding constraints inside the model resolve against it
            prog = build_cell(arch, shape_name, mesh, compress=compress,
                              overrides=overrides, remat=remat)
            jitted = jax.jit(prog.fn, in_shardings=prog.in_shardings,
                             out_shardings=prog.out_shardings,
                             donate_argnums=prog.donate)
            lowered = jitted.lower(*prog.args)
            compiled = lowered.compile()
        mem = compiled.memory_analysis()
        cost = compat.cost_analysis(compiled)
        text = compiled.as_text()
        rep = analyze_compiled_text(
            text, arch=arch, shape=shape, mesh_name=mesh_name,
            n_chips=mesh.devices.size, hw=TPU_V5E, cfg=cfg, cost=cost,
            memory_stats=_mem_dict(mem))
        record.update(
            status="ok",
            kind=prog.kind,
            compile_s=time.time() - t0,
            memory=_mem_dict(mem),
            cost={k: cost.get(k) for k in ("flops", "bytes accessed",
                                           "transcendentals")},
            roofline=dataclasses.asdict(rep),
            hlo_bytes=len(text),
        )
        if verbose:
            m = record["memory"]
            print(f"OK   {cell_id} [{mesh_name}] compile={record['compile_s']:.1f}s "
                  f"args={m['argument_size_in_bytes']/2**30:.2f}GiB "
                  f"temp={m['temp_size_in_bytes']/2**30:.2f}GiB "
                  f"out={m['output_size_in_bytes']/2**30:.2f}GiB")
            print("     " + rep.summary())
    except SkipCell as e:
        record.update(status="skip", reason=str(e))
        if verbose:
            print(f"SKIP {cell_id} [{mesh_name}]: {e}")
    except Exception as e:
        record.update(status="fail", error=f"{type(e).__name__}: {e}",
                      traceback=traceback.format_exc()[-4000:])
        if verbose:
            print(f"FAIL {cell_id} [{mesh_name}]: {type(e).__name__}: {e}")
    _write(out_dir, mesh_name, cell_id, record)
    return record


def _mem_dict(mem) -> dict:
    keys = ("argument_size_in_bytes", "output_size_in_bytes",
            "temp_size_in_bytes", "alias_size_in_bytes",
            "generated_code_size_in_bytes")
    return {k: getattr(mem, k, None) for k in keys}


def _write(out_dir: str, mesh_name: str, cell_id: str, record: dict) -> None:
    d = os.path.join(out_dir, mesh_name)
    os.makedirs(d, exist_ok=True)
    with open(os.path.join(d, f"{cell_id}.json"), "w") as f:
        json.dump(record, f, indent=1, default=str)


def main() -> None:
    ap = argparse.ArgumentParser(description=_DOC)
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--all", action="store_true",
                    help="every (arch x shape) cell")
    ap.add_argument("--compress", default=None, choices=(None, "int8"))
    ap.add_argument("--out", default="experiments/dryrun")
    ap.add_argument("--tag", default="")
    args = ap.parse_args()

    from repro.configs import ARCHS, SHAPES, canonical

    cells: list[tuple[str, str]] = []
    if args.all:
        for a in ARCHS:
            for s in SHAPES:
                cells.append((a, s))
    else:
        if not args.arch or not args.shape:
            ap.error("--arch and --shape (or --all) required")
        cells.append((canonical(args.arch), args.shape))

    n_ok = n_skip = n_fail = 0
    for arch, shape in cells:
        rec = run_cell(arch, shape, multi_pod=args.multi_pod,
                       out_dir=args.out, compress=args.compress,
                       tag=args.tag)
        n_ok += rec["status"] == "ok"
        n_skip += rec["status"] == "skip"
        n_fail += rec["status"] == "fail"
    print(f"\ndry-run complete: {n_ok} ok, {n_skip} skip, {n_fail} fail")
    if n_fail:
        raise SystemExit(1)


if __name__ == "__main__":
    main()

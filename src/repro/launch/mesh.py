"""Production meshes. A FUNCTION, not a module-level constant — importing
this module never touches jax device state (the dry-run sets
--xla_force_host_platform_device_count before any jax import)."""
from __future__ import annotations

from repro import compat
from repro.configs.base import MULTI_POD, SINGLE_POD


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return compat.make_mesh(shape, axes)


def mesh_config(*, multi_pod: bool = False):
    return MULTI_POD if multi_pod else SINGLE_POD

"""Pallas kernel layer: instruction-granularity noise injection (the TPU
analogue of the paper's inline-asm patterns).

Each kernel package pairs a Pallas implementation (``kernel.py``, with a
static-k and a runtime-k entry point — see ``noise_slots`` for the protocol)
with a jitted public wrapper (``ops.py``) and a pure-jnp oracle (``ref.py``).
``region.pallas_region`` adapts any of them to the Controller/Campaign spine.
"""
from repro.kernels.noise_slots import (  # noqa: F401
    K_MAX,
    MODES,
    emit_noise,
    emit_noise_rt,
)
from repro.kernels.region import KERNEL_MODES, pallas_region  # noqa: F401

"""Kernel-level noise slots — instruction-granularity injection inside Pallas
kernels (the closest TPU analogue of the paper's inline-asm patterns).

Every noisy kernel in this package takes a trailing ``noise_ref`` input block
(128×128, disjoint from kernel semantics — the paper's R_n ∩ R_s = ∅) and a
dedicated ``nacc`` output block (8×128) that all grid steps revisit; the
accumulated noise value is the DCE-proof aux output AND a correctness oracle
(its exact value is predictable, so tests assert the payload executed).

Modes (DESIGN.md §2 table):
  fp    — k VPU vector adds on the accumulator              (fp_add64)
  mxu   — k small (8×128)·(128×128) MXU dots                (fp FMA throughput)
  vmem  — k re-reads of the kernel's own input block at
          rotating offsets (always VMEM-resident)           (l1_ld64)

HBM-level noise is injected at the graph level (core.noise) — inside a Pallas
kernel every ref the body touches is already VMEM-resident by construction,
so "memory noise" belongs to the pipeline/DMA layer, not the body.
"""
from __future__ import annotations

import jax.numpy as jnp
from jax.experimental import pallas as pl

NOISE_SHAPE = (8, 128)          # one VREG row group
NOISE_REF_SHAPE = (128, 128)    # MXU-aligned noise operand

MODES = ("none", "fp", "mxu", "vmem")


def noise_in_spec(grid_ndim: int) -> pl.BlockSpec:
    """The (128,128) noise operand: same block for every grid step."""
    return pl.BlockSpec(NOISE_REF_SHAPE, lambda *ids: (0, 0))


def noise_out_spec(grid_ndim: int) -> pl.BlockSpec:
    """The (8,128) noise accumulator: all grid steps revisit block (0,0)."""
    return pl.BlockSpec(NOISE_SHAPE, lambda *ids: (0, 0))


def noise_out_shape(dtype=jnp.float32):
    import jax

    return jax.ShapeDtypeStruct(NOISE_SHAPE, dtype)


def init_noise(nacc_ref, is_first):
    @pl.when(is_first)
    def _():
        nacc_ref[...] = jnp.zeros_like(nacc_ref)


def emit_noise(mode: str, k: int, nacc_ref, noise_ref, src_ref=None,
               step=0) -> None:
    """Emit ``k`` patterns of ``mode`` into the kernel body.

    ``step``: a traced or static per-grid-step index used to rotate vmem
    offsets (defeats CSE the same way the paper rotates registers).
    """
    if mode == "none" or k == 0:
        return
    if mode == "fp":
        c = noise_ref[0:8, :]
        for _ in range(k):
            nacc_ref[...] += c
    elif mode == "mxu":
        a = noise_ref[0:8, :]
        b = noise_ref[...]
        for _ in range(k):
            nacc_ref[...] += jnp.dot(a, b, preferred_element_type=jnp.float32
                                     ).astype(nacc_ref.dtype)
    elif mode == "vmem":
        src = src_ref if src_ref is not None else noise_ref
        rows = src.shape[0]
        for j in range(k):
            off = (step * 7 + j * 13) % max(rows - 8, 1)
            blk = src[pl.ds(off, 8), 0:128]
            nacc_ref[...] += blk.astype(nacc_ref.dtype)
    else:
        raise ValueError(f"unknown kernel noise mode {mode!r}; one of {MODES}")


def expected_fp_noise(noise: jnp.ndarray, k: int, n_steps: int) -> jnp.ndarray:
    """Oracle for mode='fp': nacc = k * n_steps * noise[0:8, :]."""
    return k * n_steps * noise[0:8, :].astype(jnp.float32)

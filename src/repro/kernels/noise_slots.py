"""Kernel-level noise slots — instruction-granularity injection inside Pallas
kernels (the closest TPU analogue of the paper's inline-asm patterns).

Every noisy kernel in this package takes a trailing ``noise_ref`` input block
(128×128, disjoint from kernel semantics — the paper's R_n ∩ R_s = ∅) and a
dedicated ``nacc`` output block (8×128) that all grid steps revisit; the
accumulated noise value is the DCE-proof aux output AND a correctness oracle
(its exact value is predictable, so tests assert the payload executed).

Modes (DESIGN.md §2 table):
  fp    — k VPU vector adds on the accumulator              (fp_add64)
  mxu   — k small (8×128)·(128×128) MXU dots                (fp FMA throughput)
  vmem  — k re-reads of the kernel's own input block at
          rotating offsets (always VMEM-resident)           (l1_ld64)

HBM-level noise is injected at the graph level (core.noise) — inside a Pallas
kernel every ref the body touches is already VMEM-resident by construction,
so "memory noise" belongs to the pipeline/DMA layer, not the body.

Runtime-k protocol (compile-once sweeps)
----------------------------------------
``emit_noise`` bakes ``k`` into the trace as a static Python int — the
paper's cost model, one Mosaic compile per sweep point. ``emit_noise_rt`` is
its compile-once twin: ``k`` is a TRACED int32 scalar, delivered to the
kernel as a scalar-prefetch operand (``compat.prefetch_scalar_grid_spec``,
the SMEM scalar ref that is resident before the body runs), and the patterns
are emitted by a bounded ``lax.fori_loop``:

  * the trip count is ``clip(k, 0, K_MAX)`` — ``K_MAX`` caps the payload a
    single grid step can emit (the controller's widest sweep tops out at
    k=320, comfortably inside the bound) so the accumulator oracle stays
    exact and a corrupt/hostile k cannot run the kernel away;
  * pattern j of the runtime path computes EXACTLY the arithmetic of pattern
    j of the static path (same addends, same offsets, same order), so for
    any k ≤ K_MAX the two paths are bitwise identical — asserted per kernel
    and mode in tests/test_kernels.py;
  * payload verification still happens on a STATIC trace: the compiled
    runtime-k HLO holds ONE pattern in a loop body, so surviving-op counts
    (or here, the exact ``nacc`` oracle) are checked on a ``k_noise``-static
    build — the controller's ≤2-executables-per-sweep budget (runtime-k
    sweep + static payload check);
  * the trace-per-k fallback (``Controller(compile_once=False)``) still
    applies when a region cannot thread a traced k — e.g. a hand-rolled
    ``pallas_call`` without the scalar-prefetch operand, or a k that changes
    buffer SHAPES rather than a loop trip count.
"""
from __future__ import annotations

import os

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

NOISE_SHAPE = (8, 128)          # one VREG row group
NOISE_REF_SHAPE = (128, 128)    # MXU-aligned noise operand

MODES = ("none", "fp", "mxu", "vmem")

# Upper bound on the runtime noise quantity a single grid step may emit.
# ``emit_noise_rt`` clips its traced k to [0, K_MAX]; every controller sweep
# schedule stays below it (max scheduled k: 320).
K_MAX = 512


def noise_in_spec(grid_ndim: int) -> pl.BlockSpec:
    """The (128,128) noise operand: same block for every grid step.

    The star-args index map also absorbs the trailing scalar-prefetch ref
    on the runtime-k path, so one spec serves both.
    """
    return pl.BlockSpec(NOISE_REF_SHAPE, lambda *ids: (0, 0))


def noise_out_spec(grid_ndim: int) -> pl.BlockSpec:
    """The (8,128) noise accumulator: all grid steps revisit block (0,0)."""
    return pl.BlockSpec(NOISE_SHAPE, lambda *ids: (0, 0))


def noise_out_shape(dtype=jnp.float32):
    import jax

    return jax.ShapeDtypeStruct(NOISE_SHAPE, dtype)


def init_noise(nacc_ref, is_first):
    @pl.when(is_first)
    def _():
        nacc_ref[...] = jnp.zeros_like(nacc_ref)


def k_operand(k) -> jax.Array:
    """Shape the (possibly traced) noise quantity into the (1,) int32 array
    the scalar-prefetch slot expects."""
    return jnp.reshape(jnp.asarray(k, jnp.int32), (1,))


def _fp_c(noise_ref, src_ref):
    """The (8,128) addend of one fp pattern.

    With a dedicated noise operand: its first row group. Without one
    (``noise_ref=None`` — e.g. spmv_ell), the addend is derived from a
    RUNTIME block of the kernel's own input: a compile-time-constant addend
    would let the compiler strength-reduce the k-iteration add chain to one
    ``nacc += k*c`` (killing the payload the sweep is supposed to measure),
    while a data-dependent addend keeps every add live AND keeps the exact
    ``nacc`` oracle (tests derive the same value from the host copy).

    ``REPRO_NOISE_SABOTAGE=const`` deliberately reintroduces that bug — a
    compile-time-constant addend — so the static audit's fail-fast path
    (``repro.analysis``, the CI audit-smoke job) can be exercised against a
    payload XLA really does fold away. Never set it in a measuring run.
    """
    if os.environ.get("REPRO_NOISE_SABOTAGE") == "const":
        return jnp.full(NOISE_SHAPE, 1.0, jnp.float32)
    if noise_ref is not None:
        return noise_ref[0:8, :]
    if src_ref is None:
        raise ValueError("fp noise needs a noise operand or a src_ref to "
                         "derive its addend from")
    col = src_ref[0:8, 0:1].astype(jnp.float32)
    return jnp.broadcast_to(col, NOISE_SHAPE)


def _vmem_width(src) -> int:
    """vmem patterns read ``(8, w)`` blocks: full 128 lanes when the source
    block is wide enough, its own width otherwise (e.g. narrow ELL blocks)."""
    return min(src.shape[1], NOISE_SHAPE[1])


def emit_noise(mode: str, k: int, nacc_ref, noise_ref, src_ref=None,
               step=0) -> None:
    """Emit ``k`` patterns of ``mode`` into the kernel body (k static).

    ``step``: a traced or static per-grid-step index used to rotate vmem
    offsets (defeats CSE the same way the paper rotates registers).
    """
    if mode == "none" or k == 0:
        return
    if mode == "fp":
        c = _fp_c(noise_ref, src_ref)
        for _ in range(k):
            nacc_ref[...] += c
    elif mode == "mxu":
        a = noise_ref[0:8, :]
        b = noise_ref[...]
        for _ in range(k):
            nacc_ref[...] += jnp.dot(a, b, preferred_element_type=jnp.float32
                                     ).astype(nacc_ref.dtype)
    elif mode == "vmem":
        src = src_ref if src_ref is not None else noise_ref
        rows = src.shape[0]
        w = _vmem_width(src)
        for j in range(k):
            off = (step * 7 + j * 13) % max(rows - 8, 1)
            blk = src[pl.ds(off, 8), 0:w]
            nacc_ref[0:8, 0:w] += blk.astype(nacc_ref.dtype)
    else:
        raise ValueError(f"unknown kernel noise mode {mode!r}; one of {MODES}")


def emit_noise_rt(mode: str, k, nacc_ref, noise_ref, src_ref=None,
                  step=0, k_max: int = K_MAX) -> None:
    """``emit_noise`` with ``k`` a TRACED int32 scalar (runtime-k protocol).

    Patterns come out of a bounded ``lax.fori_loop`` whose trip count is
    ``clip(k, 0, k_max)``; iteration j performs exactly the arithmetic of
    static pattern j (same addends/offsets, same order), so the two paths
    are bitwise identical for any k ≤ ``k_max``. One compiled executable
    serves the whole k-sweep.
    """
    if mode == "none":
        return
    kk = jnp.clip(jnp.asarray(k, jnp.int32), 0, k_max)
    if mode == "fp":
        c = _fp_c(noise_ref, src_ref)
        nacc_ref[...] = jax.lax.fori_loop(
            0, kk, lambda j, acc: acc + c, nacc_ref[...])
    elif mode == "mxu":
        a = noise_ref[0:8, :]
        b = noise_ref[...]

        def one(j, acc):
            return acc + jnp.dot(a, b, preferred_element_type=jnp.float32
                                 ).astype(acc.dtype)

        nacc_ref[...] = jax.lax.fori_loop(0, kk, one, nacc_ref[...])
    elif mode == "vmem":
        src = src_ref if src_ref is not None else noise_ref
        rows = src.shape[0]
        w = _vmem_width(src)

        def one(j, acc):
            off = (step * 7 + j * 13) % max(rows - 8, 1)
            blk = src[pl.ds(off, 8), 0:w].astype(acc.dtype)
            if w < NOISE_SHAPE[1]:
                # zero-pad to full lanes instead of acc.at[:, :w].add —
                # the scatter that .at lowers to captures a rank-1 index
                # constant, which pallas_call rejects; lanes >= w only ever
                # hold +0.0, so the pad-add is bitwise-identical
                blk = jnp.pad(blk, ((0, 0), (0, NOISE_SHAPE[1] - w)))
            return acc + blk

        nacc_ref[...] = jax.lax.fori_loop(0, kk, one, nacc_ref[...])
    else:
        raise ValueError(f"unknown kernel noise mode {mode!r}; one of {MODES}")


def expected_fp_noise(noise: jnp.ndarray, k: int, n_steps: int) -> jnp.ndarray:
    """Oracle for mode='fp': nacc = k * n_steps * noise[0:8, :]."""
    return k * n_steps * noise[0:8, :].astype(jnp.float32)

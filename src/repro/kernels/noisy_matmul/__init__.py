from repro.kernels.noisy_matmul.ops import noisy_matmul  # noqa: F401

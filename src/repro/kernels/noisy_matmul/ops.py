"""jit'd public wrapper: backend dispatch + noise plumbing."""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.kernels.noise_slots import NOISE_REF_SHAPE
from repro.kernels.noisy_matmul.kernel import matmul_pallas, matmul_pallas_rt
from repro.kernels.noisy_matmul.ref import matmul_ref


def default_noise_operand(dtype=jnp.float32):
    return (jnp.arange(NOISE_REF_SHAPE[0] * NOISE_REF_SHAPE[1], dtype=jnp.float32)
            .reshape(NOISE_REF_SHAPE) * 1e-6).astype(dtype)


@partial(jax.jit, static_argnames=("mode", "k_noise", "bm", "bn", "bk",
                                   "backend"))
def noisy_matmul(a, b, noise=None, *, mode: str = "none", k_noise: int = 0,
                 bm: int = 256, bn: int = 256, bk: int = 256,
                 backend: str = "auto"):
    """Matmul with optional kernel-level noise.

    backend: "pallas" (TPU), "interpret" (CPU validation), "ref" (oracle),
    "auto" (pallas on TPU, interpret elsewhere).
    Returns (out, nacc); nacc is zeros for mode="none".
    """
    if noise is None:
        noise = default_noise_operand(a.dtype)
    if backend == "auto":
        backend = "pallas" if jax.default_backend() == "tpu" else "interpret"
    if backend == "ref":
        return matmul_ref(a, b), jnp.zeros((8, 128), jnp.float32)
    return matmul_pallas(a, b, noise, mode=mode, k_noise=k_noise,
                         bm=bm, bn=bn, bk=bk,
                         interpret=(backend == "interpret"))


@partial(jax.jit, static_argnames=("mode", "bm", "bn", "bk", "backend"))
def noisy_matmul_rt(k, a, b, noise=None, *, mode: str = "fp",
                    bm: int = 256, bn: int = 256, bk: int = 256,
                    backend: str = "auto"):
    """Runtime-k matmul: ``k`` is a traced int32 operand (compile-once
    sweeps). Pattern-for-pattern identical to ``noisy_matmul(..., k_noise=k)``
    for k ≤ noise_slots.K_MAX."""
    if noise is None:
        noise = default_noise_operand(a.dtype)
    if backend == "auto":
        backend = "pallas" if jax.default_backend() == "tpu" else "interpret"
    return matmul_pallas_rt(k, a, b, noise, mode=mode, bm=bm, bn=bn, bk=bk,
                            interpret=(backend == "interpret"))

"""Pure-jnp oracle for the noisy matmul kernel."""
from __future__ import annotations

import jax.numpy as jnp


def matmul_ref(a, b):
    return jnp.dot(a.astype(jnp.float32), b.astype(jnp.float32)
                   ).astype(a.dtype)


def fp_noise_ref(noise, k_noise: int, n_grid_steps: int):
    """nacc oracle for mode='fp'."""
    return k_noise * n_grid_steps * noise[0:8, :].astype(jnp.float32)

"""Tiled TPU matmul with instruction-level noise slots.

Grid (M/bm, N/bn, K/bk), K innermost; f32 accumulator in VMEM scratch; block
shapes are MXU-aligned (multiples of 128 on the contracting/lane dims). The
noise slot runs after the tile FMA so the Mosaic scheduler is free to overlap
it with the next DMA — exactly the slack the absorption metric measures.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels import noise_slots as ns


def _mm_kernel(a_ref, b_ref, noise_ref, o_ref, nacc_ref, acc_ref, *,
               mode: str, k_noise: int):
    i, j, kk = pl.program_id(0), pl.program_id(1), pl.program_id(2)
    nk = pl.num_programs(2)

    @pl.when(kk == 0)
    def _():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    ns.init_noise(nacc_ref, (i == 0) & (j == 0) & (kk == 0))

    acc_ref[...] += jnp.dot(a_ref[...], b_ref[...],
                            preferred_element_type=jnp.float32)

    # noise slot: after the FMA, before the writeback
    ns.emit_noise(mode, k_noise, nacc_ref, noise_ref, src_ref=a_ref,
                  step=i * 131 + j * 17 + kk)

    @pl.when(kk == nk - 1)
    def _():
        o_ref[...] = acc_ref[...].astype(o_ref.dtype)


def matmul_pallas(a: jax.Array, b: jax.Array, noise: jax.Array, *,
                  mode: str = "none", k_noise: int = 0,
                  bm: int = 256, bn: int = 256, bk: int = 256,
                  interpret: bool = False):
    """a (M,K) @ b (K,N) -> (out (M,N), nacc (8,128) f32)."""
    M, K = a.shape
    K2, N = b.shape
    assert K == K2, (a.shape, b.shape)
    bm, bn, bk = min(bm, M), min(bn, N), min(bk, K)
    assert M % bm == 0 and N % bn == 0 and K % bk == 0, (a.shape, b.shape, (bm, bn, bk))
    grid = (M // bm, N // bn, K // bk)

    kernel = functools.partial(_mm_kernel, mode=mode, k_noise=k_noise)
    out, nacc = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, k: (i, k)),
            pl.BlockSpec((bk, bn), lambda i, j, k: (k, j)),
            ns.noise_in_spec(3),
        ],
        out_specs=[
            pl.BlockSpec((bm, bn), lambda i, j, k: (i, j)),
            ns.noise_out_spec(3),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((M, N), a.dtype),
            ns.noise_out_shape(),
        ],
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.float32)],
        interpret=interpret,
    )(a, b, noise)
    return out, nacc

"""Tiled TPU matmul with instruction-level noise slots.

Grid (M/bm, N/bn, K/bk), K innermost; f32 accumulator in VMEM scratch; block
shapes are MXU-aligned (multiples of 128 on the contracting/lane dims). The
noise slot runs after the tile FMA so the Mosaic scheduler is free to overlap
it with the next DMA — exactly the slack the absorption metric measures.

Two entry points share one body: ``matmul_pallas`` bakes ``k_noise`` into the
trace (one executable per sweep point — the paper's cost model), while
``matmul_pallas_rt`` takes k as a scalar-prefetch int32 operand and emits
patterns through the bounded runtime-k loop (``noise_slots.emit_noise_rt``) —
one executable serves the whole sweep, bitwise identical per (mode, k).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro import compat
from repro.kernels import noise_slots as ns

# star-args tails absorb the scalar-prefetch ref on the runtime-k path, so
# the same index maps serve both pallas_call signatures
_A_SPEC = lambda bm, bk: pl.BlockSpec((bm, bk), lambda i, j, k, *_: (i, k))
_B_SPEC = lambda bk, bn: pl.BlockSpec((bk, bn), lambda i, j, k, *_: (k, j))
_O_SPEC = lambda bm, bn: pl.BlockSpec((bm, bn), lambda i, j, k, *_: (i, j))


def _mm_body(a_ref, b_ref, noise_ref, o_ref, nacc_ref, acc_ref, emit):
    i, j, kk = pl.program_id(0), pl.program_id(1), pl.program_id(2)
    nk = pl.num_programs(2)

    @pl.when(kk == 0)
    def _():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    ns.init_noise(nacc_ref, (i == 0) & (j == 0) & (kk == 0))

    acc_ref[...] += jnp.dot(a_ref[...], b_ref[...],
                            preferred_element_type=jnp.float32)

    # noise slot: after the FMA, before the writeback
    emit(nacc_ref, noise_ref, a_ref, i * 131 + j * 17 + kk)

    @pl.when(kk == nk - 1)
    def _():
        o_ref[...] = acc_ref[...].astype(o_ref.dtype)


def _mm_kernel(a_ref, b_ref, noise_ref, o_ref, nacc_ref, acc_ref, *,
               mode: str, k_noise: int):
    _mm_body(a_ref, b_ref, noise_ref, o_ref, nacc_ref, acc_ref,
             lambda nacc, nz, src, step: ns.emit_noise(
                 mode, k_noise, nacc, nz, src_ref=src, step=step))


def _mm_kernel_rt(k_ref, a_ref, b_ref, noise_ref, o_ref, nacc_ref, acc_ref,
                  *, mode: str):
    _mm_body(a_ref, b_ref, noise_ref, o_ref, nacc_ref, acc_ref,
             lambda nacc, nz, src, step: ns.emit_noise_rt(
                 mode, k_ref[0], nacc, nz, src_ref=src, step=step))


def _mm_shapes(a, b, bm, bn, bk):
    M, K = a.shape
    K2, N = b.shape
    assert K == K2, (a.shape, b.shape)
    bm, bn, bk = min(bm, M), min(bn, N), min(bk, K)
    assert M % bm == 0 and N % bn == 0 and K % bk == 0, (a.shape, b.shape,
                                                        (bm, bn, bk))
    return M, N, K, bm, bn, bk


def matmul_pallas(a: jax.Array, b: jax.Array, noise: jax.Array, *,
                  mode: str = "none", k_noise: int = 0,
                  bm: int = 256, bn: int = 256, bk: int = 256,
                  interpret: bool = False):
    """a (M,K) @ b (K,N) -> (out (M,N), nacc (8,128) f32). Static k."""
    M, N, K, bm, bn, bk = _mm_shapes(a, b, bm, bn, bk)
    grid = (M // bm, N // bn, K // bk)

    kernel = functools.partial(_mm_kernel, mode=mode, k_noise=k_noise)
    out, nacc = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            _A_SPEC(bm, bk),
            _B_SPEC(bk, bn),
            ns.noise_in_spec(3),
        ],
        out_specs=[
            _O_SPEC(bm, bn),
            ns.noise_out_spec(3),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((M, N), a.dtype),
            ns.noise_out_shape(),
        ],
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.float32)],
        interpret=interpret,
    )(a, b, noise)
    return out, nacc


def matmul_pallas_rt(k, a: jax.Array, b: jax.Array, noise: jax.Array, *,
                     mode: str = "fp",
                     bm: int = 256, bn: int = 256, bk: int = 256,
                     interpret: bool = False):
    """Runtime-k twin of ``matmul_pallas``: ``k`` is a traced int32 delivered
    via scalar prefetch; one executable serves the whole k-sweep."""
    M, N, K, bm, bn, bk = _mm_shapes(a, b, bm, bn, bk)
    grid = (M // bm, N // bn, K // bk)

    grid_spec = compat.prefetch_scalar_grid_spec(
        num_scalar_prefetch=1,
        grid=grid,
        in_specs=[
            _A_SPEC(bm, bk),
            _B_SPEC(bk, bn),
            ns.noise_in_spec(3),
        ],
        out_specs=[
            _O_SPEC(bm, bn),
            ns.noise_out_spec(3),
        ],
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.float32)],
    )
    out, nacc = pl.pallas_call(
        functools.partial(_mm_kernel_rt, mode=mode),
        grid_spec=grid_spec,
        out_shape=[
            jax.ShapeDtypeStruct((M, N), a.dtype),
            ns.noise_out_shape(),
        ],
        interpret=interpret,
    )(ns.k_operand(k), a, b, noise)
    return out, nacc

"""Pure-jnp oracle: full-materialization masked softmax attention (f32)."""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def attention_ref(q, k, v, *, causal: bool = True, window: int = 0):
    """q (B,H,Sq,hd); k,v (B,KH,Sk,hd) -> (B,H,Sq,hd)."""
    B, H, Sq, hd = q.shape
    KH = k.shape[1]
    if KH != H:
        k = jnp.repeat(k, H // KH, axis=1)
        v = jnp.repeat(v, H // KH, axis=1)
    scale = 1.0 / math.sqrt(hd)
    s = jnp.einsum("bhqd,bhkd->bhqk", q.astype(jnp.float32) * scale,
                   k.astype(jnp.float32))
    qpos = jnp.arange(Sq)[:, None]
    kpos = jnp.arange(k.shape[2])[None, :]
    keep = jnp.ones((Sq, k.shape[2]), bool)
    if causal:
        keep &= qpos >= kpos
    if window:
        keep &= qpos - kpos < window
    s = jnp.where(keep[None, None], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bhqk,bhkd->bhqd", p, v.astype(jnp.float32))
    return out.astype(q.dtype)

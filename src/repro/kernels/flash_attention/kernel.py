"""Flash attention (forward) for TPU: online-softmax blocked attention with
GQA, causal and sliding-window masks, and an instruction-level noise slot.

Grid (B*H, Sq/bq, Sk/bk), kv innermost. Blocks: q (1,bq,hd), k/v (1,bk,hd);
f32 running max / sum / accumulator live in VMEM scratch shaped (bq,128) /
(bq,128) / (bq,hd) (the 128-lane replication matches the official TPU flash
kernels — scalar-per-row state is stored broadcast along lanes).

Causal skip: kv blocks entirely above the diagonal are skipped (pl.when), so
compiled FLOPs stay ~S²/2 — visible in the roofline accounting. Sliding
window additionally skips blocks entirely below the window.

``flash_attention_pallas_rt`` is the compile-once twin: the noise quantity is
a scalar-prefetch int32 operand and patterns come from the bounded runtime-k
loop (noise_slots.emit_noise_rt) — one executable per (mode,) serves the
whole k-sweep, bitwise identical to the static path.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro import compat
from repro.kernels import noise_slots as ns

NEG_INF = -1e30


def _fa_body(q_ref, k_ref, v_ref, noise_ref, o_ref, nacc_ref,
             m_ref, l_ref, acc_ref, emit, *, scale: float, causal: bool,
             window: int, bq: int, bk: int):
    bh, qi, ki = pl.program_id(0), pl.program_id(1), pl.program_id(2)
    nk = pl.num_programs(2)

    @pl.when(ki == 0)
    def _():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    ns.init_noise(nacc_ref, (bh == 0) & (qi == 0) & (ki == 0))

    q0 = qi * bq                      # first q position of this block
    k0 = ki * bk

    # block-level skip conditions (both resolve at run time on the grid ids)
    live = jnp.bool_(True)
    if causal:
        live &= k0 <= q0 + bq - 1               # not entirely above diagonal
    if window:
        live &= q0 - (k0 + bk - 1) < window     # not entirely out of window

    @pl.when(live)
    def _():
        q = q_ref[0].astype(jnp.float32) * scale          # (bq, hd)
        k = k_ref[0].astype(jnp.float32)                  # (bk, hd)
        v = v_ref[0].astype(jnp.float32)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32)  # (bq,bk)
        qpos = q0 + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
        kpos = k0 + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
        keep = jnp.ones((bq, bk), jnp.bool_)
        if causal:
            keep &= qpos >= kpos
        if window:
            keep &= qpos - kpos < window
        s = jnp.where(keep, s, NEG_INF)

        m_prev = m_ref[:, 0:1]                            # (bq,1)
        m_cur = jnp.max(s, axis=1, keepdims=True)
        m_new = jnp.maximum(m_prev, m_cur)
        p = jnp.exp(s - m_new)
        corr = jnp.exp(m_prev - m_new)                    # (bq,1)
        l_new = corr * l_ref[:, 0:1] + jnp.sum(p, axis=1, keepdims=True)
        acc_ref[...] = acc_ref[...] * corr + jnp.dot(
            p, v, preferred_element_type=jnp.float32)
        m_ref[...] = jnp.broadcast_to(m_new, m_ref.shape)
        l_ref[...] = jnp.broadcast_to(l_new, l_ref.shape)

        emit(nacc_ref, noise_ref, bh * 131 + qi * 17 + ki)

    @pl.when(ki == nk - 1)
    def _():
        l = l_ref[:, 0:1]
        safe = jnp.where(l == 0.0, 1.0, l)
        o_ref[0, ...] = (acc_ref[...] / safe).astype(o_ref.dtype)


def _fa_kernel(q_ref, k_ref, v_ref, noise_ref, o_ref, nacc_ref,
               m_ref, l_ref, acc_ref, *, scale: float, causal: bool,
               window: int, bq: int, bk: int, mode: str, k_noise: int):
    _fa_body(q_ref, k_ref, v_ref, noise_ref, o_ref, nacc_ref,
             m_ref, l_ref, acc_ref,
             lambda nacc, nz, step: ns.emit_noise(
                 mode, k_noise, nacc, nz, src_ref=None, step=step),
             scale=scale, causal=causal, window=window, bq=bq, bk=bk)


def _fa_kernel_rt(kq_ref, q_ref, k_ref, v_ref, noise_ref, o_ref, nacc_ref,
                  m_ref, l_ref, acc_ref, *, scale: float, causal: bool,
                  window: int, bq: int, bk: int, mode: str):
    _fa_body(q_ref, k_ref, v_ref, noise_ref, o_ref, nacc_ref,
             m_ref, l_ref, acc_ref,
             lambda nacc, nz, step: ns.emit_noise_rt(
                 mode, kq_ref[0], nacc, nz, src_ref=None, step=step),
             scale=scale, causal=causal, window=window, bq=bq, bk=bk)


def _fa_setup(q, k, v, bq, bk):
    B, H, Sq, hd = q.shape
    _, KH, Sk, _ = k.shape
    assert H % KH == 0, (H, KH)
    G = H // KH
    bq = min(bq, Sq)
    bk = min(bk, Sk)
    assert Sq % bq == 0 and Sk % bk == 0, (Sq, Sk, bq, bk)
    grid = (B * H, Sq // bq, Sk // bk)
    scale = 1.0 / math.sqrt(hd)

    qf = q.reshape(B * H, Sq, hd)
    kf = k.reshape(B * KH, Sk, hd)
    vf = v.reshape(B * KH, Sk, hd)

    def kv_idx(bh, qi, ki, *_):
        b = bh // H
        h = bh % H
        return (b * KH + h // G, ki, 0)

    in_specs = [
        pl.BlockSpec((1, bq, hd), lambda bh, qi, ki, *_: (bh, qi, 0)),
        pl.BlockSpec((1, bk, hd), kv_idx),
        pl.BlockSpec((1, bk, hd), kv_idx),
        ns.noise_in_spec(3),
    ]
    out_specs = [
        pl.BlockSpec((1, bq, hd), lambda bh, qi, ki, *_: (bh, qi, 0)),
        ns.noise_out_spec(3),
    ]
    out_shape = [
        jax.ShapeDtypeStruct((B * H, Sq, hd), q.dtype),
        ns.noise_out_shape(),
    ]
    scratch = [
        pltpu.VMEM((bq, 128), jnp.float32),   # running max
        pltpu.VMEM((bq, 128), jnp.float32),   # running sum
        pltpu.VMEM((bq, hd), jnp.float32),    # output accumulator
    ]
    return (B, H, Sq, hd, bq, bk, grid, scale, (qf, kf, vf),
            in_specs, out_specs, out_shape, scratch)


def flash_attention_pallas(q, k, v, noise, *, causal: bool = True,
                           window: int = 0, bq: int = 128, bk: int = 128,
                           mode: str = "none", k_noise: int = 0,
                           interpret: bool = False):
    """q (B,H,Sq,hd); k,v (B,KH,Sk,hd) -> (out (B,H,Sq,hd), nacc (8,128))."""
    (B, H, Sq, hd, bq, bk, grid, scale, flat, in_specs, out_specs,
     out_shape, scratch) = _fa_setup(q, k, v, bq, bk)

    kernel = functools.partial(_fa_kernel, scale=scale, causal=causal,
                               window=window, bq=bq, bk=bk, mode=mode,
                               k_noise=k_noise)
    out, nacc = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=in_specs,
        out_specs=out_specs,
        out_shape=out_shape,
        scratch_shapes=scratch,
        interpret=interpret,
    )(*flat, noise)
    return out.reshape(B, H, Sq, hd), nacc


def flash_attention_pallas_rt(kq, q, k, v, noise, *, causal: bool = True,
                              window: int = 0, bq: int = 128, bk: int = 128,
                              mode: str = "fp", interpret: bool = False):
    """Runtime-k twin of ``flash_attention_pallas`` (``kq``: the traced
    noise quantity; named to avoid clashing with the key tensor ``k``)."""
    (B, H, Sq, hd, bq, bk, grid, scale, flat, in_specs, out_specs,
     out_shape, scratch) = _fa_setup(q, k, v, bq, bk)

    grid_spec = compat.prefetch_scalar_grid_spec(
        num_scalar_prefetch=1,
        grid=grid,
        in_specs=in_specs,
        out_specs=out_specs,
        scratch_shapes=scratch,
    )
    out, nacc = pl.pallas_call(
        functools.partial(_fa_kernel_rt, scale=scale, causal=causal,
                          window=window, bq=bq, bk=bk, mode=mode),
        grid_spec=grid_spec,
        out_shape=out_shape,
        interpret=interpret,
    )(ns.k_operand(kq), *flat, noise)
    return out.reshape(B, H, Sq, hd), nacc

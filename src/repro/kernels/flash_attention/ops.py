"""jit'd public wrapper with backend dispatch."""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.kernels.flash_attention.kernel import (flash_attention_pallas,
                                                  flash_attention_pallas_rt)
from repro.kernels.flash_attention.ref import attention_ref
from repro.kernels.noisy_matmul.ops import default_noise_operand


@partial(jax.jit, static_argnames=("causal", "window", "bq", "bk", "mode",
                                   "k_noise", "backend"))
def flash_attention(q, k, v, noise=None, *, causal: bool = True,
                    window: int = 0, bq: int = 128, bk: int = 128,
                    mode: str = "none", k_noise: int = 0,
                    backend: str = "auto"):
    """Blocked attention. Returns (out, nacc)."""
    if noise is None:
        noise = default_noise_operand(jnp.float32)
    if backend == "auto":
        backend = "pallas" if jax.default_backend() == "tpu" else "interpret"
    if backend == "ref":
        return (attention_ref(q, k, v, causal=causal, window=window),
                jnp.zeros((8, 128), jnp.float32))
    return flash_attention_pallas(q, k, v, noise, causal=causal,
                                  window=window, bq=bq, bk=bk, mode=mode,
                                  k_noise=k_noise,
                                  interpret=(backend == "interpret"))


@partial(jax.jit, static_argnames=("causal", "window", "bq", "bk", "mode",
                                   "backend"))
def flash_attention_rt(kq, q, k, v, noise=None, *, causal: bool = True,
                       window: int = 0, bq: int = 128, bk: int = 128,
                       mode: str = "fp", backend: str = "auto"):
    """Runtime-k blocked attention: ``kq`` is a traced int32 noise quantity
    (compile-once sweeps), pattern-identical to
    ``flash_attention(..., k_noise=kq)`` for kq ≤ noise_slots.K_MAX."""
    if noise is None:
        noise = default_noise_operand(jnp.float32)
    if backend == "auto":
        backend = "pallas" if jax.default_backend() == "tpu" else "interpret"
    return flash_attention_pallas_rt(kq, q, k, v, noise, causal=causal,
                                     window=window, bq=bq, bk=bk, mode=mode,
                                     interpret=(backend == "interpret"))

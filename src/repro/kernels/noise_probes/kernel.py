"""Pure-noise calibration kernels.

On real TPU hardware, timing ``run_probe(mode, k, n_steps)`` against k gives
the per-pattern cost δ of each noise mode — the constant the analytic
saturation model needs (core.analytic.pattern_deltas provides spec-sheet
values; this kernel measures them). On CPU the kernel validates in interpret
mode: the accumulated value is exactly predictable, proving each pattern
executed exactly once (static payload check at the arithmetic level).

``probe_pallas_rt`` is the compile-once twin: the noise quantity is a
scalar-prefetch int32 operand (runtime-k protocol, see noise_slots) — the
calibration sweep over k reuses ONE executable per mode.
"""
from __future__ import annotations

import functools

import jax
from jax.experimental import pallas as pl

from repro import compat
from repro.kernels import noise_slots as ns


def _probe_kernel(noise_ref, nacc_ref, *, mode: str, k_noise: int):
    i = pl.program_id(0)
    ns.init_noise(nacc_ref, i == 0)
    ns.emit_noise(mode, k_noise, nacc_ref, noise_ref, src_ref=noise_ref,
                  step=i)


def _probe_kernel_rt(k_ref, noise_ref, nacc_ref, *, mode: str):
    i = pl.program_id(0)
    ns.init_noise(nacc_ref, i == 0)
    ns.emit_noise_rt(mode, k_ref[0], nacc_ref, noise_ref, src_ref=noise_ref,
                     step=i)


def probe_pallas(noise, *, mode: str, k_noise: int, n_steps: int,
                 interpret: bool = False):
    kernel = functools.partial(_probe_kernel, mode=mode, k_noise=k_noise)
    return pl.pallas_call(
        kernel,
        grid=(n_steps,),
        in_specs=[ns.noise_in_spec(1)],
        out_specs=ns.noise_out_spec(1),
        out_shape=ns.noise_out_shape(),
        interpret=interpret,
    )(noise)


def probe_pallas_rt(k, noise, *, mode: str, n_steps: int,
                    interpret: bool = False):
    grid_spec = compat.prefetch_scalar_grid_spec(
        num_scalar_prefetch=1,
        grid=(n_steps,),
        in_specs=[ns.noise_in_spec(1)],
        out_specs=ns.noise_out_spec(1),
    )
    return pl.pallas_call(
        functools.partial(_probe_kernel_rt, mode=mode),
        grid_spec=grid_spec,
        out_shape=ns.noise_out_shape(),
        interpret=interpret,
    )(ns.k_operand(k), noise)

"""Pure-noise calibration kernels.

On real TPU hardware, timing ``run_probe(mode, k, n_steps)`` against k gives
the per-pattern cost δ of each noise mode — the constant the analytic
saturation model needs (core.analytic.pattern_deltas provides spec-sheet
values; this kernel measures them). On CPU the kernel validates in interpret
mode: the accumulated value is exactly predictable, proving each pattern
executed exactly once (static payload check at the arithmetic level).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels import noise_slots as ns


def _probe_kernel(noise_ref, nacc_ref, *, mode: str, k_noise: int):
    i = pl.program_id(0)
    ns.init_noise(nacc_ref, i == 0)
    ns.emit_noise(mode, k_noise, nacc_ref, noise_ref, src_ref=noise_ref,
                  step=i)


def probe_pallas(noise, *, mode: str, k_noise: int, n_steps: int,
                 interpret: bool = False):
    kernel = functools.partial(_probe_kernel, mode=mode, k_noise=k_noise)
    return pl.pallas_call(
        kernel,
        grid=(n_steps,),
        in_specs=[ns.noise_in_spec(1)],
        out_specs=ns.noise_out_spec(1),
        out_shape=ns.noise_out_shape(),
        interpret=interpret,
    )(noise)

"""jit'd public wrapper with backend dispatch."""
from __future__ import annotations

from functools import partial

import jax

from repro.kernels.noise_probes.kernel import probe_pallas
from repro.kernels.noise_probes.ref import probe_ref
from repro.kernels.noisy_matmul.ops import default_noise_operand


@partial(jax.jit, static_argnames=("mode", "k_noise", "n_steps", "backend"))
def run_probe(noise=None, *, mode: str = "fp", k_noise: int = 1,
              n_steps: int = 128, backend: str = "auto"):
    if noise is None:
        noise = default_noise_operand()
    if backend == "auto":
        backend = "pallas" if jax.default_backend() == "tpu" else "interpret"
    if backend == "ref":
        return probe_ref(noise, mode=mode, k_noise=k_noise, n_steps=n_steps)
    return probe_pallas(noise, mode=mode, k_noise=k_noise, n_steps=n_steps,
                        interpret=(backend == "interpret"))

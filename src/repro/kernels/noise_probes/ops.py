"""jit'd public wrapper with backend dispatch."""
from __future__ import annotations

from functools import partial

import jax

from repro.kernels.noise_probes.kernel import probe_pallas, probe_pallas_rt
from repro.kernels.noise_probes.ref import probe_ref
from repro.kernels.noisy_matmul.ops import default_noise_operand


@partial(jax.jit, static_argnames=("mode", "k_noise", "n_steps", "backend"))
def run_probe(noise=None, *, mode: str = "fp", k_noise: int = 1,
              n_steps: int = 128, backend: str = "auto"):
    if noise is None:
        noise = default_noise_operand()
    if backend == "auto":
        backend = "pallas" if jax.default_backend() == "tpu" else "interpret"
    if backend == "ref":
        return probe_ref(noise, mode=mode, k_noise=k_noise, n_steps=n_steps)
    return probe_pallas(noise, mode=mode, k_noise=k_noise, n_steps=n_steps,
                        interpret=(backend == "interpret"))


@partial(jax.jit, static_argnames=("mode", "n_steps", "backend"))
def run_probe_rt(k, noise=None, *, mode: str = "fp", n_steps: int = 128,
                 backend: str = "auto"):
    """Runtime-k calibration probe: ``k`` is a traced int32 operand, so the
    per-pattern-cost sweep reuses one executable per mode."""
    if noise is None:
        noise = default_noise_operand()
    if backend == "auto":
        backend = "pallas" if jax.default_backend() == "tpu" else "interpret"
    return probe_pallas_rt(k, noise, mode=mode, n_steps=n_steps,
                           interpret=(backend == "interpret"))

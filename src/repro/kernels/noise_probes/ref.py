"""Oracles for the probe kernel: exact accumulated value per mode."""
from __future__ import annotations

import jax.numpy as jnp


def probe_ref(noise, *, mode: str, k_noise: int, n_steps: int):
    nf = noise.astype(jnp.float32)
    if mode == "none" or k_noise == 0:
        return jnp.zeros((8, 128), jnp.float32)
    if mode == "fp":
        return k_noise * n_steps * nf[0:8, :]
    if mode == "mxu":
        one = jnp.dot(nf[0:8, :], nf, preferred_element_type=jnp.float32)
        return k_noise * n_steps * one
    if mode == "vmem":
        acc = jnp.zeros((8, 128), jnp.float32)
        rows = noise.shape[0]
        for i in range(n_steps):
            for j in range(k_noise):
                off = (i * 7 + j * 13) % max(rows - 8, 1)
                acc = acc + nf[off:off + 8, 0:128]
        return acc
    raise ValueError(mode)

from repro.kernels.noise_probes.ops import run_probe  # noqa: F401

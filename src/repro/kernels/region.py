"""``pallas_region`` — RegionTargets over the Pallas kernel layer.

The loop-level (``loop_region``) and graph-level (``step_region``) injection
sites have ridden the Controller/Campaign spine since PR 1; this adapter puts
the instruction-granularity Pallas kernels on the same spine:

  * ``build(mode, k)``    — one static-k executable (trace-per-k fallback);
  * ``build_rt(mode)``    — ONE runtime-k executable per (kernel, mode): the
    noise quantity is a scalar-prefetch operand of the kernel (noise_slots
    runtime-k protocol), so ``Controller.run_mode`` sweeps a whole k-grid on
    ≤2 executables (runtime-k sweep + static payload check) instead of one
    per k — the paper's "Fast: ✗" concession, escaped at the last layer that
    still paid it;
  * campaigns persist/replay (region, mode, k, t) records for Pallas regions
    exactly like any other RegionTarget — a completed Pallas campaign
    replays with zero new measurements;
  * payload verification runs on a STATIC trace, but at the arithmetic
    level: instead of counting surviving scope-tagged HLO ops (Pallas bodies
    carry no ``named_scope`` metadata through lowering), the check runs the
    static-k kernel once and compares ``nacc`` against the exact per-mode
    oracle — stronger than op counting, since the accumulated value pins
    both that ALL k patterns executed and that none was duplicated.

Backends: "interpret" (CPU validation — the container has no TPU; also what
benchmarks/CI drive), "pallas" (real TPU), "auto".
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.controller import RegionTarget
from repro.core.payload import InjectionReport
from repro.kernels import noise_slots as ns
from repro.kernels.flash_attention.kernel import (flash_attention_pallas,
                                                  flash_attention_pallas_rt)
from repro.kernels.noise_probes.kernel import probe_pallas, probe_pallas_rt
from repro.kernels.noise_probes.ref import probe_ref
from repro.kernels.noisy_matmul.kernel import matmul_pallas, matmul_pallas_rt
from repro.kernels.noisy_matmul.ops import default_noise_operand
from repro.kernels.spmv_ell.kernel import spmv_ell_pallas, spmv_ell_pallas_rt
from repro.kernels.spmv_ell.ref import (fp_noise_ell_ref, make_band_ell,
                                        vmem_noise_ell_ref)

# noise modes each kernel supports (spmv has no VMEM noise operand -> no mxu)
KERNEL_MODES = {
    "matmul": ("fp", "mxu", "vmem"),
    "spmxv": ("fp", "vmem"),
    "attention": ("fp", "mxu", "vmem"),
    "probe": ("fp", "mxu", "vmem"),
}

# per-kernel meaning of the one "size" knob a family sweeps, its default, and
# the block width it must tile (sizes below one block are allowed: the block
# shrinks; 'probe' counts grid steps — any positive size is fine)
SIZE_KW = {"matmul": "n", "spmxv": "n", "attention": "seq", "probe": "n_steps"}
SIZE_DEFAULT = {"matmul": 256, "spmxv": 512, "attention": 128, "probe": 64}
SIZE_ALIGN = {"matmul": 128, "spmxv": 128, "attention": 64, "probe": 1}


def validate_size(kernel: str, n: int) -> None:
    """The size rule every entry point (probe CLI, fleet plans, families)
    shares: noise patterns read 8-row groups, and sizes past one block must
    tile evenly."""
    if kernel not in SIZE_KW:
        raise ValueError(f"unknown pallas kernel {kernel!r}; "
                         f"one of {sorted(SIZE_KW)}")
    align = SIZE_ALIGN[kernel]
    if n < 1:
        raise ValueError(f"size for {kernel!r} must be positive; got {n}")
    if align > 1 and (n < 8 or (n > align and n % align)):
        raise ValueError(
            f"size for {kernel!r} must be >= 8 and a multiple of its "
            f"{align}-wide block (or smaller than one block); got {n}")

# which resource one pattern of each kernel mode stresses (payload reports)
MODE_TARGETS = {"fp": "compute", "mxu": "compute", "vmem": "vmem"}


# region-name derivation, shared by the spec builders below and by
# ``family_names`` (cheap grid queries — fleet status/inspect must learn a
# family's region names without building a single jax array). Defaults here
# mirror the builder signatures; ``test_pallas_region`` pins the agreement.
def _matmul_name(*, n=256, **_):
    return f"pallas_matmul_n{n}"


def _spmxv_name(*, n=512, nnz_per_row=16, q=0.0, **_):
    return f"pallas_spmxv_n{n}_L{nnz_per_row}_q" + f"{q:g}".replace(".", "p")


def _attention_name(*, batch=1, heads=2, seq=128, head_dim=64, **_):
    return f"pallas_attn_b{batch}h{heads}s{seq}d{head_dim}"


def _probe_name(*, n_steps=64, **_):
    return f"pallas_probe_s{n_steps}"


_NAMERS = {"matmul": _matmul_name, "spmxv": _spmxv_name,
           "attention": _attention_name, "probe": _probe_name}


@dataclasses.dataclass(frozen=True)
class _KernelSpec:
    """Everything ``pallas_region`` needs about one kernel: its arguments,
    its static-k and runtime-k callables, and the exact nacc oracle."""
    name: str
    args: tuple
    static_fn: Callable[[str, int], Callable]   # (mode, k) -> fn(*args)
    rt_fn: Callable[[str], Callable]            # mode -> fn(k, *args)
    oracle: Callable[[str, int], Optional[jnp.ndarray]]
    n_steps: int                                # grid steps visiting the slot
    body_size: int                              # |l1.l2| stand-in for Abs^rel


def _matmul_spec(interpret: bool, *, n: int = 256, bm: int = 128,
                 bn: int = 128, bk: int = 128) -> _KernelSpec:
    a = jax.random.normal(jax.random.PRNGKey(0), (n, n), jnp.float32)
    b = jax.random.normal(jax.random.PRNGKey(1), (n, n), jnp.float32)
    noise = default_noise_operand()
    bm, bn, bk = min(bm, n), min(bn, n), min(bk, n)
    grid_steps = (n // bm) * (n // bn) * (n // bk)

    def static_fn(mode, k):
        return lambda a, b, noise: matmul_pallas(
            a, b, noise, mode=mode, k_noise=k, bm=bm, bn=bn, bk=bk,
            interpret=interpret)

    def rt_fn(mode):
        return lambda k, a, b, noise: matmul_pallas_rt(
            k, a, b, noise, mode=mode, bm=bm, bn=bn, bk=bk,
            interpret=interpret)

    def oracle(mode, k):
        if mode == "fp":
            return ns.expected_fp_noise(noise, k, grid_steps)
        return None

    return _KernelSpec(_matmul_name(n=n), (a, b, noise), static_fn,
                       rt_fn, oracle, grid_steps, body_size=3)


def _spmxv_spec(interpret: bool, *, n: int = 512, nnz_per_row: int = 16,
                q: float = 0.0, br: int = 128, seed: int = 0) -> _KernelSpec:
    vals, cols = make_band_ell(n, nnz_per_row, q, seed=seed)
    x = jnp.asarray(np.random.RandomState(seed + 1)
                    .standard_normal(n).astype(np.float32))
    br = min(br, n)
    nb = n // br

    def static_fn(mode, k):
        return lambda vals, cols, x: spmv_ell_pallas(
            vals, cols, x, br=br, mode=mode, k_noise=k, interpret=interpret)

    def rt_fn(mode):
        return lambda k, vals, cols, x: spmv_ell_pallas_rt(
            k, vals, cols, x, br=br, mode=mode, interpret=interpret)

    def oracle(mode, k):
        if mode == "fp":
            return fp_noise_ell_ref(vals, k, br)
        if mode == "vmem":
            return vmem_noise_ell_ref(vals, k, br)
        return None

    return _KernelSpec(_spmxv_name(n=n, nnz_per_row=nnz_per_row, q=q),
                       (vals, cols, x), static_fn, rt_fn, oracle, nb,
                       body_size=4)


def _attention_spec(interpret: bool, *, batch: int = 1, heads: int = 2,
                    kv_heads: int = 2, seq: int = 128, head_dim: int = 64,
                    bq: int = 64, bk: int = 64, causal: bool = True
                    ) -> _KernelSpec:
    keys = jax.random.split(jax.random.PRNGKey(0), 3)
    q = jax.random.normal(keys[0], (batch, heads, seq, head_dim), jnp.float32)
    k = jax.random.normal(keys[1], (batch, kv_heads, seq, head_dim),
                          jnp.float32)
    v = jax.random.normal(keys[2], (batch, kv_heads, seq, head_dim),
                          jnp.float32)
    noise = default_noise_operand()
    bq, bk = min(bq, seq), min(bk, seq)
    # only LIVE kv blocks visit the noise slot (causal skip)
    nq, nk = seq // bq, seq // bk
    live = sum(1 for qi in range(nq) for ki in range(nk)
               if not causal or ki * bk <= qi * bq + bq - 1)
    grid_steps = batch * heads * live

    def static_fn(mode, kn):
        return lambda q, k, v, noise: flash_attention_pallas(
            q, k, v, noise, causal=causal, bq=bq, bk=bk, mode=mode,
            k_noise=kn, interpret=interpret)

    def rt_fn(mode):
        return lambda kn, q, k, v, noise: flash_attention_pallas_rt(
            kn, q, k, v, noise, causal=causal, bq=bq, bk=bk, mode=mode,
            interpret=interpret)

    def oracle(mode, kn):
        if mode == "fp":
            return ns.expected_fp_noise(noise, kn, grid_steps)
        return None

    return _KernelSpec(_attention_name(batch=batch, heads=heads, seq=seq,
                                       head_dim=head_dim),
                       (q, k, v, noise), static_fn, rt_fn, oracle,
                       grid_steps, body_size=12)


def _probe_spec(interpret: bool, *, n_steps: int = 64) -> _KernelSpec:
    noise = default_noise_operand()

    def static_fn(mode, k):
        return lambda noise: probe_pallas(
            noise, mode=mode, k_noise=k, n_steps=n_steps,
            interpret=interpret)

    def rt_fn(mode):
        return lambda k, noise: probe_pallas_rt(
            k, noise, mode=mode, n_steps=n_steps, interpret=interpret)

    def oracle(mode, k):
        return probe_ref(noise, mode=mode, k_noise=k, n_steps=n_steps)

    return _KernelSpec(_probe_name(n_steps=n_steps), (noise,), static_fn,
                       rt_fn, oracle, n_steps, body_size=1)


_SPECS = {
    "matmul": _matmul_spec,
    "spmxv": _spmxv_spec,
    "attention": _attention_spec,
    "probe": _probe_spec,
}


def _nacc_of(result):
    return result[-1] if isinstance(result, (tuple, list)) else result


def pallas_region(kernel: str, *, backend: str = "auto", name: str = "",
                  trace_hook: Optional[Callable[[], None]] = None,
                  **sizes) -> RegionTarget:
    """A RegionTarget over one Pallas kernel, ready for
    ``Controller.characterize`` / ``Campaign.sweep_mode``.

    ``trace_hook`` (tests): called once per Python trace of any executable
    this region builds — each jit compilation traces exactly once, so the
    hook counts compiled executables (the ≤2-per-sweep guarantee).
    ``sizes``: forwarded to the kernel's spec builder (e.g. ``n=``, ``q=``).
    """
    if kernel not in _SPECS:
        raise ValueError(f"unknown pallas kernel {kernel!r}; "
                         f"one of {sorted(_SPECS)}")
    interpret = (backend == "interpret"
                 or (backend == "auto" and jax.default_backend() != "tpu"))
    spec = _SPECS[kernel](interpret, **sizes)
    modes = KERNEL_MODES[kernel]

    def _jit(fn):
        if trace_hook is None:
            return jax.jit(fn)

        def counted(*args):
            trace_hook()
            return fn(*args)

        return jax.jit(counted)

    def _check_mode(mode):
        if mode not in modes:
            raise ValueError(f"kernel {kernel!r} supports noise modes "
                             f"{modes}, not {mode!r}")

    def build(mode: str, k: int):
        if not mode or k == 0:
            return _jit(spec.static_fn("none", 0))
        _check_mode(mode)
        return _jit(spec.static_fn(mode, k))

    def args_for(mode: str, k: int):
        return spec.args

    def build_rt(mode: str):
        _check_mode(mode)
        return _jit(spec.rt_fn(mode))

    def args_for_rt(mode: str):
        return spec.args

    def payload_check(mode: str, k: int) -> Optional[InjectionReport]:
        """Arithmetic-level static payload check: run the static-k build
        once; an exact oracle match (or a nonzero accumulator for modes
        without a closed-form oracle) proves all k patterns executed."""
        _check_mode(mode)
        nacc = np.asarray(_nacc_of(build(mode, k)(*spec.args)), np.float32)
        want = spec.oracle(mode, k)
        if want is not None:
            ok = np.allclose(nacc, np.asarray(want, np.float32),
                             rtol=1e-4, atol=1e-5)
        else:
            ok = bool(np.abs(nacc).sum() > 0) if k else True
        return InjectionReport(
            mode=mode, target=MODE_TARGETS[mode], expected=k,
            payload=k if ok else 0, overhead=0,
            payload_dynamic=k * spec.n_steps, body_ops=spec.body_size)

    return RegionTarget(name=name or spec.name, build=build,
                        args_for=args_for, body_size=spec.body_size,
                        payload_target=dict(MODE_TARGETS),
                        build_rt=build_rt, args_for_rt=args_for_rt,
                        payload_check=payload_check,
                        # Pallas bodies lose named-scope metadata in
                        # lowering: the audit censuses everything and lets
                        # the two-point k-delta isolate the noise
                        audit_hint={"scoped": False, "in_loop": True,
                                    "steps": spec.n_steps})


def family_params(kernel: str) -> frozenset:
    """Keyword params the kernel's spec builder accepts — the allowlist
    plan validation checks declarative params against."""
    import inspect

    sig = inspect.signature(_SPECS[kernel])
    return frozenset(p.name for p in sig.parameters.values()
                     if p.kind == p.KEYWORD_ONLY)


def check_family_args(kernel: str, sizes, qs, common: dict) -> None:
    """The family argument rules, shared by ``pallas_family``,
    ``family_names`` and SweepPlan validation — so a bad family is rejected
    when the plan is BUILT, not when a worker subprocess resolves it."""
    if kernel not in _SPECS:
        raise ValueError(f"unknown pallas kernel {kernel!r}; "
                         f"one of {sorted(_SPECS)}")
    if qs is not None and kernel != "spmxv":
        raise ValueError(f"qs= applies to the 'spmxv' kernel only, "
                         f"not {kernel!r}")
    allowed = family_params(kernel) - {SIZE_KW[kernel], "q"}
    bad = sorted(set(common) - allowed)
    if bad:
        raise ValueError(f"kernel {kernel!r} spec does not accept param(s) "
                         f"{bad}; allowed: {sorted(allowed)}")
    for n in sizes:
        validate_size(kernel, int(n))


def _family_grid(kernel: str, sizes, qs):
    for n in sizes:
        for q in (qs if qs is not None else (None,)):
            kw = {SIZE_KW[kernel]: int(n)}
            if q is not None:
                kw["q"] = float(q)
            yield kw


def family_names(kernel: str, sizes, *, qs=None, **common) -> list[str]:
    """The region names ``pallas_family(kernel, sizes, qs=qs, **common)``
    would produce, WITHOUT building a single jax array — what fleet
    status/inspect/launch use to enumerate a plan's grid cheaply."""
    check_family_args(kernel, sizes, qs, common)
    return [_NAMERS[kernel](**{**common, **kw})
            for kw in _family_grid(kernel, sizes, qs)]


def pallas_family(kernel: str, sizes, *, qs=None, backend: str = "auto",
                  trace_hook: Optional[Callable[[], None]] = None,
                  **common) -> list[RegionTarget]:
    """One RegionTarget per size (× swap probability q for spmxv), sharing
    one campaign-store namespace.

    The grid a kernel's characterization really spans is a size/q FAMILY —
    fig4 sweeps matmul n, fig7 sweeps the spmxv (n, q) plane — and every
    member's spec encodes its coordinates in the region name, so a single
    campaign store (and a single fleet plan) holds the whole family's
    (region, mode, k, t) records side by side. ``sizes`` drives the kernel's
    size knob (``SIZE_KW``); ``qs`` is spmxv-only; ``common`` (e.g.
    ``nnz_per_row=``) is forwarded to every member's spec builder.
    """
    check_family_args(kernel, sizes, qs, common)
    out = [pallas_region(kernel, backend=backend, trace_hook=trace_hook,
                         **{**common, **kw})
           for kw in _family_grid(kernel, sizes, qs)]
    names = [r.name for r in out]
    if len(set(names)) != len(names):
        raise ValueError(f"family members collide in one store namespace: "
                         f"{names}")
    return out

"""jit'd public wrapper with backend dispatch."""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.kernels.spmv_ell.kernel import spmv_ell_pallas, spmv_ell_pallas_rt
from repro.kernels.spmv_ell.ref import spmv_ell_ref


@partial(jax.jit, static_argnames=("br", "mode", "k_noise", "backend"))
def spmv_ell(vals, cols, x, *, br: int = 128, mode: str = "none",
             k_noise: int = 0, backend: str = "auto"):
    """ELL SPMV. Returns (y (R,), nacc (8,128))."""
    if backend == "auto":
        backend = "pallas" if jax.default_backend() == "tpu" else "interpret"
    if backend == "ref":
        return spmv_ell_ref(vals, cols, x), jnp.zeros((8, 128), jnp.float32)
    return spmv_ell_pallas(vals, cols, x, br=br, mode=mode, k_noise=k_noise,
                           interpret=(backend == "interpret"))


@partial(jax.jit, static_argnames=("br", "mode", "backend"))
def spmv_ell_rt(k, vals, cols, x, *, br: int = 128, mode: str = "fp",
                backend: str = "auto"):
    """Runtime-k ELL SPMV: ``k`` is a traced int32 operand (compile-once
    sweeps), pattern-identical to ``spmv_ell(..., k_noise=k)`` for
    k ≤ noise_slots.K_MAX."""
    if backend == "auto":
        backend = "pallas" if jax.default_backend() == "tpu" else "interpret"
    return spmv_ell_pallas_rt(k, vals, cols, x, br=br, mode=mode,
                              interpret=(backend == "interpret"))
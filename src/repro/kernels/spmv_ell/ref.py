"""Pure-jnp oracle for ELL SPMV + the ELL matrix generators used by the
SPMXV case study (band matrix with swap probability q, paper §6)."""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np


def spmv_ell_ref(vals, cols, x):
    """y[r] = sum_l vals[r,l] * x[cols[r,l]] (padded entries have vals=0)."""
    g = jnp.take(x, cols, axis=0)
    return jnp.sum(vals.astype(jnp.float32) * g.astype(jnp.float32),
                   axis=1).astype(x.dtype)


def make_band_ell(n: int, nnz_per_row: int, q: float, seed: int = 0,
                  dtype=np.float32):
    """Banded sparse matrix in ELL with the paper's swap-probability q.

    At q=0 the nonzeros of row r sit at columns r-w..r+w (stride-1 vector
    access, prefetch friendly). Each nonzero is swapped with probability q to
    a uniformly random column — monotonically increasing the irregularity of
    the x gather, exactly the paper's knob for driving SPMXV from
    bandwidth-bound to latency-bound.
    """
    rng = np.random.RandomState(seed)
    w = nnz_per_row // 2
    base = np.arange(n)[:, None] + (np.arange(nnz_per_row)[None, :] - w)
    cols = np.clip(base, 0, n - 1).astype(np.int32)
    swap = rng.random_sample(cols.shape) < q
    cols[swap] = rng.randint(0, n, size=int(swap.sum()), dtype=np.int32)
    vals = rng.random_sample(cols.shape).astype(dtype) * 0.1
    return jnp.asarray(vals), jnp.asarray(cols)

"""Pure-jnp oracle for ELL SPMV + the ELL matrix generators used by the
SPMXV case study (band matrix with swap probability q, paper §6)."""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np


def spmv_ell_ref(vals, cols, x):
    """y[r] = sum_l vals[r,l] * x[cols[r,l]] (padded entries have vals=0)."""
    g = jnp.take(x, cols, axis=0)
    return jnp.sum(vals.astype(jnp.float32) * g.astype(jnp.float32),
                   axis=1).astype(x.dtype)


def fp_noise_ell_ref(vals, k_noise: int, br: int = 128):
    """Exact nacc oracle for spmv_ell mode='fp'.

    The kernel has no noise operand; block i's addend is its first 8 rows'
    first column broadcast across lanes (noise_slots._fp_c with a src_ref),
    so nacc = k * sum_i broadcast(vals[i*br : i*br+8, 0]).
    """
    R = vals.shape[0]
    br = min(br, R)
    c = sum(vals[i * br:i * br + 8, 0:1].astype(jnp.float32)
            for i in range(R // br))
    return k_noise * jnp.broadcast_to(c, (8, 128))


def vmem_noise_ell_ref(vals, k_noise: int, br: int = 128):
    """Exact nacc oracle for spmv_ell mode='vmem': block i re-reads its own
    (8, min(L,128)) row groups at rotating offsets (step index = i)."""
    R, L = vals.shape
    br = min(br, R)
    w = min(L, 128)
    acc = jnp.zeros((8, 128), jnp.float32)
    for i in range(R // br):
        blk = vals[i * br:(i + 1) * br].astype(jnp.float32)
        for j in range(k_noise):
            off = (i * 7 + j * 13) % max(br - 8, 1)
            acc = acc.at[:, 0:w].add(blk[off:off + 8, 0:w])
    return acc


def make_band_ell(n: int, nnz_per_row: int, q: float, seed: int = 0,
                  dtype=np.float32):
    """Banded sparse matrix in ELL with the paper's swap-probability q.

    At q=0 the nonzeros of row r sit at columns r-w..r+w (stride-1 vector
    access, prefetch friendly). Each nonzero is swapped with probability q to
    a uniformly random column — monotonically increasing the irregularity of
    the x gather, exactly the paper's knob for driving SPMXV from
    bandwidth-bound to latency-bound.
    """
    rng = np.random.RandomState(seed)
    w = nnz_per_row // 2
    base = np.arange(n)[:, None] + (np.arange(nnz_per_row)[None, :] - w)
    cols = np.clip(base, 0, n - 1).astype(np.int32)
    swap = rng.random_sample(cols.shape) < q
    cols[swap] = rng.randint(0, n, size=int(swap.sum()), dtype=np.int32)
    vals = rng.random_sample(cols.shape).astype(dtype) * 0.1
    return jnp.asarray(vals), jnp.asarray(cols)

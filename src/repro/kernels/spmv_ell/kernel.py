"""ELL-format sparse matrix-vector product for TPU — the paper's SPMXV case
study kernel (§6), adapted from CSR to the TPU-friendly ELL layout.

CSR's per-row variable nnz serializes badly on a vector unit; ELL pads every
row to L nonzeros so the kernel is a dense (br, L) multiply + gather —
rethinking the access pattern for the MXU/VPU instead of porting the CPU loop
(DESIGN.md hardware adaptation). The irregular part — the x gather through
``cols`` — is exactly what the paper's swap probability q randomizes, and the
gather locality is what moves the kernel between bandwidth- and latency-bound
regimes.

Blocks: vals/cols (br, L); x fully VMEM-resident (1, N) — valid for the case
study sizes (N ≤ ~1M f32 = 4 MiB... for larger N shard rows over the grid and
x over a second grid axis; see ops.py). y written as (nb, br) so the lane dim
stays 128-aligned. Vector gather lowering on TPU requires a recent Mosaic;
correctness is validated in interpret mode on CPU (the container has no TPU).

Noise: this kernel has no dedicated noise operand — fp noise derives its
addend from a RUNTIME block of ``vals`` (first rows of the current block;
``noise_slots._fp_c``). A compile-time-constant addend would let the
compiler strength-reduce the k-iteration add chain to one ``nacc += k*c``,
silently deleting the payload the sweep measures; the data-dependent addend
keeps every add live and keeps the exact ``nacc`` oracle
(``ref.fp_noise_ell_ref``). vmem noise re-reads the vals block at rotating
offsets. ``spmv_ell_pallas_rt`` is the compile-once twin (runtime-k protocol,
see noise_slots).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro import compat
from repro.kernels import noise_slots as ns


def _spmv_body(vals_ref, cols_ref, x_ref, y_ref, nacc_ref, emit):
    i = pl.program_id(0)
    ns.init_noise(nacc_ref, i == 0)

    vals = vals_ref[...].astype(jnp.float32)        # (br, L)
    cols = cols_ref[...]                            # (br, L) int32
    x = x_ref[0]                                    # (N,)
    g = jnp.take(x, cols, axis=0).astype(jnp.float32)
    y_ref[0, ...] = jnp.sum(vals * g, axis=1).astype(y_ref.dtype)

    # noise slot: both modes feed off the vals block (fp derives its addend
    # from it, vmem re-reads it) — R_n ∩ R_s = ∅ still holds: nacc is a
    # dedicated output, vals is only ever read.
    emit(nacc_ref, vals_ref, i)


def _spmv_kernel(vals_ref, cols_ref, x_ref, y_ref, nacc_ref, *,
                 mode: str, k_noise: int):
    _spmv_body(vals_ref, cols_ref, x_ref, y_ref, nacc_ref,
               lambda nacc, vals, step: ns.emit_noise(
                   mode, k_noise, nacc, None, src_ref=vals, step=step))


def _spmv_kernel_rt(k_ref, vals_ref, cols_ref, x_ref, y_ref, nacc_ref, *,
                    mode: str):
    _spmv_body(vals_ref, cols_ref, x_ref, y_ref, nacc_ref,
               lambda nacc, vals, step: ns.emit_noise_rt(
                   mode, k_ref[0], nacc, None, src_ref=vals, step=step))


def _spmv_shapes(vals, x, br):
    R, L = vals.shape
    br = min(br, R)
    assert R % br == 0, (R, br)
    assert br >= 8, (br, "noise patterns read 8-row groups of the block")
    return R, L, br, R // br, x.shape[0]


def _spmv_specs(br, L, N):
    return (
        [
            pl.BlockSpec((br, L), lambda i, *_: (i, 0)),
            pl.BlockSpec((br, L), lambda i, *_: (i, 0)),
            pl.BlockSpec((1, N), lambda i, *_: (0, 0)),
        ],
        [
            pl.BlockSpec((1, br), lambda i, *_: (i, 0)),
            ns.noise_out_spec(1),
        ],
    )


def spmv_ell_pallas(vals, cols, x, *, br: int = 128, mode: str = "none",
                    k_noise: int = 0, interpret: bool = False):
    """vals,cols (R,L); x (N,) -> (y (R,), nacc). Static k."""
    R, L, br, nb, N = _spmv_shapes(vals, x, br)
    in_specs, out_specs = _spmv_specs(br, L, N)
    kernel = functools.partial(_spmv_kernel, mode=mode, k_noise=k_noise)
    y, nacc = pl.pallas_call(
        kernel,
        grid=(nb,),
        in_specs=in_specs,
        out_specs=out_specs,
        out_shape=[
            jax.ShapeDtypeStruct((nb, br), x.dtype),
            ns.noise_out_shape(),
        ],
        interpret=interpret,
    )(vals, cols, x[None, :])
    return y.reshape(R), nacc


def spmv_ell_pallas_rt(k, vals, cols, x, *, br: int = 128, mode: str = "fp",
                       interpret: bool = False):
    """Runtime-k twin of ``spmv_ell_pallas``: one executable per mode serves
    the whole k-sweep (scalar-prefetch delivery)."""
    R, L, br, nb, N = _spmv_shapes(vals, x, br)
    in_specs, out_specs = _spmv_specs(br, L, N)
    grid_spec = compat.prefetch_scalar_grid_spec(
        num_scalar_prefetch=1,
        grid=(nb,),
        in_specs=in_specs,
        out_specs=out_specs,
    )
    y, nacc = pl.pallas_call(
        functools.partial(_spmv_kernel_rt, mode=mode),
        grid_spec=grid_spec,
        out_shape=[
            jax.ShapeDtypeStruct((nb, br), x.dtype),
            ns.noise_out_shape(),
        ],
        interpret=interpret,
    )(ns.k_operand(k), vals, cols, x[None, :])
    return y.reshape(R), nacc

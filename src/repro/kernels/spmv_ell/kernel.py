"""ELL-format sparse matrix-vector product for TPU — the paper's SPMXV case
study kernel (§6), adapted from CSR to the TPU-friendly ELL layout.

CSR's per-row variable nnz serializes badly on a vector unit; ELL pads every
row to L nonzeros so the kernel is a dense (br, L) multiply + gather —
rethinking the access pattern for the MXU/VPU instead of porting the CPU loop
(DESIGN.md hardware adaptation). The irregular part — the x gather through
``cols`` — is exactly what the paper's swap probability q randomizes, and the
gather locality is what moves the kernel between bandwidth- and latency-bound
regimes.

Blocks: vals/cols (br, L); x fully VMEM-resident (1, N) — valid for the case
study sizes (N ≤ ~1M f32 = 4 MiB... for larger N shard rows over the grid and
x over a second grid axis; see ops.py). y written as (nb, br) so the lane dim
stays 128-aligned. Vector gather lowering on TPU requires a recent Mosaic;
correctness is validated in interpret mode on CPU (the container has no TPU).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels import noise_slots as ns


def _spmv_kernel(vals_ref, cols_ref, x_ref, y_ref, nacc_ref, *,
                 mode: str, k_noise: int):
    i = pl.program_id(0)
    ns.init_noise(nacc_ref, i == 0)

    vals = vals_ref[...].astype(jnp.float32)        # (br, L)
    cols = cols_ref[...]                            # (br, L) int32
    x = x_ref[0]                                    # (N,)
    g = jnp.take(x, cols, axis=0).astype(jnp.float32)
    y_ref[0, ...] = jnp.sum(vals * g, axis=1).astype(y_ref.dtype)

    # noise slot: vmem mode re-reads the vals block (this kernel has no
    # dedicated noise operand — fp noise synthesizes its constant in VREGs).
    if mode == "vmem" and k_noise:
        ns.emit_noise("vmem", k_noise, nacc_ref, vals_ref, src_ref=vals_ref,
                      step=i)
    elif mode == "fp" and k_noise:
        c = jnp.full((8, 128), 1e-6, jnp.float32)
        for _ in range(k_noise):
            nacc_ref[...] += c


def spmv_ell_pallas(vals, cols, x, *, br: int = 128, mode: str = "none",
                    k_noise: int = 0, interpret: bool = False):
    """vals,cols (R,L); x (N,) -> (y (R,), nacc)."""
    R, L = vals.shape
    br = min(br, R)
    assert R % br == 0, (R, br)
    nb = R // br
    N = x.shape[0]

    kernel = functools.partial(_spmv_kernel, mode=mode, k_noise=k_noise)
    y, nacc = pl.pallas_call(
        kernel,
        grid=(nb,),
        in_specs=[
            pl.BlockSpec((br, L), lambda i: (i, 0)),
            pl.BlockSpec((br, L), lambda i: (i, 0)),
            pl.BlockSpec((1, N), lambda i: (0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, br), lambda i: (i, 0)),
            ns.noise_out_spec(1),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((nb, br), x.dtype),
            ns.noise_out_shape(),
        ],
        interpret=interpret,
    )(vals, cols, x[None, :])
    return y.reshape(R), nacc

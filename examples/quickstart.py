"""Quickstart: the paper's technique in 30 lines.

Characterize two kernels with noise injection — a memory-bound STREAM triad
and a compute-bound HACCmk force kernel — and watch the absorption metric
separate them (paper Fig. 5 in miniature).

    PYTHONPATH=src python examples/quickstart.py

Only documented public entry points are used (``repro.bench.kernels``
region builders + ``repro.core.Controller``); docs/methodology.md maps
every paper section to its module and command.
"""
from repro.bench.kernels import haccmk_region, stream_region
from repro.core import Controller

ctl = Controller(reps=3)

print("memory-bound kernel (STREAM triad):")
rep = ctl.characterize(stream_region(n=1 << 22),
                       modes=("fp_add", "l1_ld", "mem_ld"))
print(rep.summary())

print("\ncompute-bound kernel (HACCmk):")
rep = ctl.characterize(haccmk_region(n_iter=60_000),
                       modes=("fp_add", "l1_ld", "mem_ld"))
print(rep.summary())

print("""
Reading the signatures (paper §3.2):
  - the triad absorbs dozens of fp/l1 patterns but no memory-stream noise
    -> its bottleneck is memory bandwidth; buying FLOPS won't help.
  - HACCmk absorbs data-access noise but fp noise costs immediately
    -> compute-bound; vectorize or reduce flops.
""")

"""End-to-end driver: train a ~100M-parameter dense LM for a few hundred
steps on the deterministic LCG next-token task, with checkpointing and a
simulated mid-run failure that the trainer recovers from.

    PYTHONPATH=src python examples/train_lm.py [--steps 300] [--dim 512]

On CPU this is sized to finish in minutes (~10-30M params by default; pass
--dim 768 --layers 12 for the full ~100M class on a beefier host). The same
Trainer drives the full-size configs under the production mesh (see
repro.launch.train).
"""
import argparse
import dataclasses

import jax

from repro.ckpt import CheckpointManager
from repro.configs import TrainConfig, get_config
from repro.configs.base import ShapeConfig
from repro.data.pipeline import SyntheticPipeline
from repro.models.model import build
from repro.train.trainer import Trainer


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--dim", type=int, default=192)
    ap.add_argument("--layers", type=int, default=3)
    ap.add_argument("--seq", type=int, default=96)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--vocab", type=int, default=4096)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_example_ckpt")
    ap.add_argument("--inject-failure", action="store_true", default=True)
    args = ap.parse_args()

    cfg = dataclasses.replace(
        get_config("gemma_2b"), name="example-lm",
        n_layers=args.layers, d_model=args.dim,
        n_heads=max(args.dim // 64, 1), n_kv_heads=max(args.dim // 128, 1),
        head_dim=64, d_ff=args.dim * 4, vocab_size=args.vocab)
    api = build(cfg)
    n_params = sum(x.size for x in jax.tree.leaves(
        jax.eval_shape(api.init, jax.random.PRNGKey(0))))
    print(f"model: {cfg.n_layers}L d={cfg.d_model} "
          f"params={n_params/1e6:.1f}M  task=lcg(next-token)")

    tcfg = TrainConfig(lr=1e-3, warmup_steps=20, total_steps=args.steps,
                       microbatches=1, ckpt_every=50, ckpt_dir=args.ckpt_dir)
    pipe = SyntheticPipeline(cfg, ShapeConfig("ex", "train", args.seq,
                                              args.batch), task="lcg")
    ckpt = CheckpointManager(args.ckpt_dir, keep=2)
    trainer = Trainer(api, tcfg, ckpt_manager=ckpt)
    state = trainer.init_state()

    crash = {"armed": args.inject_failure}

    def maybe_fail(step):
        if crash["armed"] and step == args.steps // 2:
            crash["armed"] = False
            print(f"*** simulated node failure at step {step} — the trainer "
                  "restores the last checkpoint and replays ***")
            raise RuntimeError("node lost")

    state, hist = trainer.run(state, pipe, steps=args.steps,
                              fail_injector=maybe_fail)
    for h in hist:
        if h["step"] % 25 == 0 or h["step"] == args.steps - 1:
            print(f"step {h['step']:4d}  loss {h['loss']:7.4f}  "
                  f"gnorm {h['grad_norm']:6.2f}  {h['wall_s']*1e3:6.0f} ms")
    print(f"\nloss {hist[0]['loss']:.3f} -> {hist[-1]['loss']:.3f} over "
          f"{len(hist)} executed steps (incl. replays)")


if __name__ == "__main__":
    main()

"""Multi-host fleet flow — hosts.json, pluggable launchers, retry budgets —
runnable anywhere: the measurement is mock-backed (the deterministic
fault-injection launcher), so you see the whole spawn -> crash -> retry ->
heal -> merge -> classify arc without any real hosts.

    PYTHONPATH=src python examples/multihost_fleet.py

Swap the mock for real machines by running the same plan with:

    python -m repro.fleet run --plan PLAN --launcher ssh --hosts hosts.json
"""
import json
import os

from repro.fleet import (MockClusterLauncher, RetryBudget, SSHLauncher,
                         SweepPlan, TargetSpec, load_hosts, run_fleet)

DIR = "experiments/campaigns/fleet"
PLAN_PATH = os.path.join(DIR, "multihost_plan.json")
HOSTS_PATH = os.path.join(DIR, "hosts.json")

# -- 1. the cluster, declared once ------------------------------------------
# Only "addr" is required; python/workdir/env describe each host's checkout.
os.makedirs(DIR, exist_ok=True)
with open(HOSTS_PATH, "w") as f:
    json.dump({"hosts": [
        {"addr": "alice@n0", "python": "/opt/venv/bin/python",
         "workdir": "/scratch/repro", "env": {"PYTHONPATH": "src"}},
        {"addr": "n1", "workdir": "repro", "env": {"PYTHONPATH": "src"}},
    ]}, f, indent=1)
hosts = load_hosts(HOSTS_PATH)
print(f"hosts.json -> {HOSTS_PATH}")
ring = SSHLauncher(hosts)
for i in range(4):
    print(f"  shard {i} would run on {ring.host_for(i).addr}")

# -- 2. the plan: grid + distribution policy in one artifact ----------------
# The launcher/retry specs are part of the plan's digest — a different
# cluster layout or retry policy is a different plan identity. Here the
# plan declares the MOCK launcher (shard 0's first attempt crashes) so this
# example runs without ssh; for real hosts declare
#   launcher={"kind": "ssh", "hosts": [...]}   (or override at the CLI).
plan = SweepPlan(
    name="multihost_demo",
    store=os.path.join(DIR, "multihost_demo.jsonl"),
    targets=[TargetSpec("pallas", ("fp", "mxu"),
                        {"kernel": "probe", "sizes": [8, 16]})],
    reps=2, shards=2, backend="interpret",
    launcher={"kind": "mock", "script": {"0": ["crash"]}},
    retry={"max_attempts": 2, "backoff": 0.0})
plan.save(PLAN_PATH)
print(f"\nplan {plan.name!r} [{plan.digest()}]: {len(plan.grid())} "
      f"(region, mode) pairs -> {PLAN_PATH}")

# -- 3. run: crash on attempt 1, heal on attempt 2, merge, classify --------
# MockClusterLauncher tears shard 0's store tail exactly like a SIGKILL
# mid-append; the retry budget re-launches ONLY that shard, the store
# heals, and only the missing point is re-measured.
result = run_fleet(PLAN_PATH, resume=os.path.exists(plan.fleet_path()),
                   launcher=MockClusterLauncher({0: ["crash"]}),
                   retry=RetryBudget(max_attempts=2))

print("\nclassifications:")
for name, rep in sorted(result.reports.items()):
    print(f"  {name}: {rep.bottleneck}")

print("\nattempt ledger (fleet.json):")
for i, ss in sorted(result.state.shards.items()):
    for a in ss.attempt_log:
        print(f"  shard {i} attempt {a['attempt']}: {a['launcher']}@"
              f"{a['host']} rc={a['rc']} measured={a['measured']} "
              f"cached={a['cached']}")

print(f"\nreport: {plan.report_path()}")
print("same plan on real machines:  python -m repro.fleet run "
      f"--plan {PLAN_PATH} --launcher ssh --hosts {HOSTS_PATH}")

"""Serving example: batched generation with continuous batching on a small
dense LM — prefill builds the KV cache in one pass, finished slots are
refilled from the queue without stalling the decode batch.

    PYTHONPATH=src python examples/serve_lm.py
"""
import time

import jax
import numpy as np

from repro.configs import get_smoke_config
from repro.models.model import build
from repro.serve import ServeEngine

cfg = get_smoke_config("deepseek_coder_33b")
api = build(cfg)
params = api.init(jax.random.PRNGKey(0))

engine = ServeEngine(api, params, n_slots=4, max_seq=128, temperature=0.0)

rng = np.random.RandomState(7)
requests = []
for i in range(10):
    plen = int(rng.randint(2, 16))
    prompt = rng.randint(0, cfg.vocab_size, size=plen).tolist()
    requests.append(engine.submit(prompt, max_new=24))

t0 = time.perf_counter()
engine.run()
dt = time.perf_counter() - t0
total = sum(len(r.out) for r in requests)
print(f"served {len(requests)} requests on 4 slots: {total} tokens "
      f"in {dt:.2f}s ({total/dt:.1f} tok/s, continuous batching)")
for r in requests[:3]:
    print(f"  req{r.uid}: prompt[:4]={r.prompt[:4]} -> out[:8]={r.out[:8]}")
assert all(r.done for r in requests)

"""The paper's tool pointed at this framework's own training step: inject
noise into a (reduced) gemma train step, measure absorption, verify the
payload survived XLA, classify the bottleneck — then show the analytic
prediction for the same architecture at full scale on the TPU v5e target.

    PYTHONPATH=src python examples/probe_train_step.py
"""
import jax

from repro.configs import TPU_V5E, get_smoke_config
from repro.configs.base import ShapeConfig
from repro.core import (StepTerms, classify, predict_absorption, probe_step)
from repro.core.noise import NoiseScale, make_modes
from repro.models.model import build

cfg = get_smoke_config("gemma_2b")
api = build(cfg)
params = api.init(jax.random.PRNGKey(0))
batch = api.dummy_batch(ShapeConfig("probe", "train", 128, 4))

modes = make_modes(NoiseScale(mxu_dim=64, hbm_mib=16, chase_len=1 << 18))

print("== measured (host backend, reduced config) ==")
absorptions = {}
for name in ("fp_add32", "mxu_fma128", "vmem_ld", "hbm_stream"):
    pr = probe_step(lambda p, b: api.loss(p, b)[0], (params, batch),
                    modes[name], reps=3)
    absorptions[name] = pr.fit.k1
    print(f"  {name:12s} Abs^raw={pr.fit.k1:7.1f}  "
          f"payload={pr.injection.payload}/{pr.injection.expected} "
          f"overhead={pr.injection.overhead_fraction:.0%}")
print(" ", classify(absorptions))

print("\n== analytic (full gemma-2b train_4k on 256x TPU v5e) ==")
print("   terms from the dry-run artifact (run repro.launch.dryrun first for")
print("   live numbers; using representative values here):")
terms = StepTerms(compute=1.5e-3, memory=18e-3, ici=1.7e-3)
pred = {}
for name in ("fp_add32", "mxu_fma128", "vmem_ld", "hbm_stream"):
    fit = predict_absorption(terms, modes[name], TPU_V5E)
    pred[name] = fit.k1
    tag = "unbounded" if fit.k1 >= (1 << 20) else f"{fit.k1:9.0f}"
    print(f"  {name:12s} Abs^raw={tag}")
print(" ", classify(pred, high=1000.0))
print("\nThe memory term dominates at full scale (XLA attention materializes")
print("score tensors) -> hbm_stream noise is not absorbed; that is the")
print("bottleneck the flash-attention path removes (EXPERIMENTS.md §Perf).")

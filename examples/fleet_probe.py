"""Fleet orchestration end to end, from Python: declare a SweepPlan spanning
a Pallas kernel's whole size/q family, fan it out over 2 real subprocess
shards, merge the worker stores, classify — then prove the completed fleet
replays with ZERO new measurements.

    PYTHONPATH=src python examples/fleet_probe.py

Everything here also exists as a CLI (see docs/orchestration.md):

    python -m repro.fleet plan / run / doctor / status
    python -m repro.launch.probe --plan PLAN --shard I/N   (the worker)

For the multi-host flow (hosts.json, ssh/mock launchers, retry budgets)
see examples/multihost_fleet.py. This example imports only the documented
public entry points of ``repro.fleet``.
"""
import os

from repro.fleet import SweepPlan, TargetSpec, run_fleet

PLAN_PATH = "experiments/campaigns/fleet/example_plan.json"

# one plan = one store = the kernel's whole (size, q) grid: 2 sizes x 2 swap
# probabilities x 2 noise modes = 8 (region, mode) sweeps, split over 2 shards
plan = SweepPlan(
    name="example_spmxv_family",
    store="experiments/campaigns/fleet/example_spmxv.jsonl",
    targets=[
        TargetSpec("pallas", ("fp", "vmem"),
                   {"kernel": "spmxv", "sizes": [128, 256],
                    "qs": [0.0, 1.0], "nnz_per_row": 8}),
    ],
    reps=2, shards=2, backend="interpret")
plan.save(PLAN_PATH)
print(f"plan {plan.name!r} [{plan.digest()}]: "
      f"{len(plan.grid())} (region, mode) pairs -> {PLAN_PATH}\n")

# spawn 2 subprocess shards, stream their output, merge, classify. A killed
# shard would leave a truncated worker store; re-running this exact call with
# resume=True relaunches only the incomplete shard and heals it.
result = run_fleet(PLAN_PATH, resume=os.path.exists(plan.fleet_path()))

print("\nclassifications:")
for name, rep in sorted(result.reports.items()):
    print(f"  {name}: {rep.bottleneck}")

# the completed fleet is a durable artifact: replaying it measures nothing
replay = run_fleet(PLAN_PATH, resume=True)
assert replay.launched == [] and replay.stats.measured == 0
print(f"\nreplay: 0 launched, 0 measured, "
      f"{replay.stats.cached} points from the merged store")
print(f"report: {plan.report_path()}")
